"""Multi-query sharing: shared-plan evaluation vs per-query serial baseline.

A production monitor runs N concurrent queries over the same frames, and
the queries overlap heavily (everyone asks about the same few classes and
regions of the shared scene).  The serial baseline is what a
multi-query-unaware engine does: each registered query is its own compiled
program (``query.eval_filters``), dispatched and evaluated independently —
re-thresholding the CAM grid and re-scanning it per query, N times per
batch.  The shared engine (``core.plan.QueryPlan``) canonicalizes + dedups
the union of all leaves, evaluates each unique leaf once (counts: one
gather; Spatial: one fused (C, 5) stats reduction; Region: one summed-area
table per dilation radius) and reassembles per-query masks with incidence
einsums — one program for the whole query set.

We also report ``serial_fused`` — all N per-query evaluations traced into
a single XLA program, where CSE dedups identical leaves for free.  That is
an upper bound no serial engine reaches (it would recompile the whole
population on every registration), but it keeps us honest about how much
of the win is planning vs. mere fusion.

The second comparison (``run_adaptive``) pits the exhaustive shared plan
against the *staged adaptive* plan (``core.plan.StagedQueryPlan``) on a
skewed-selectivity workload: And-dominated queries guarded by a rarely-true
count leaf, the shape a real deployment has ("alert when >= 40 cars AND
..."), plus a sprinkle of always-true Or guards.  After a few batches of
population statistics the staged plan decides every query at the count
tier and skips the spatial/SAT stages entirely; the exhaustive plan pays
for them every batch.  Also measured on the uniform workload above, where
staging must NOT lose (all stages run; overhead is the three-valued
propagation + one (N + B,) sync per stage).

The third workload (``rowskew``) is the row-level short-circuiting case
(ISSUE 3): a shared "scene is busy" count guard that is true on ~10% of
frames, so the count tier decides ~90% of the *rows* but the spatial/SAT
tiers are still needed for the rest.  PR 2's tier-granular executor
(reproduced with ``min_bucket >= B``, i.e. row compaction disabled) runs
those tiers on the full batch; the row-compacting executor runs them on a
power-of-two bucket of undecided rows.  ``row_compaction_speedup`` in the
JSON is that head-to-head on identical queries and batches — the
filter-time improvement over the PR 2 staged numbers.

The fourth comparison (since the calibration loop closed, ISSUE 5) is
crossover-aware vs the PR 4 executor on identical queries/batches: the
PR 4 baseline hard-wires compacted ⇒ row-gather kernel and the hand-set
``min_bucket=8``, while the current executor lets the measured cost
model choose the cheaper spatial body per bucket and derive the floor.
``crossover_speedup`` in the JSON is that head-to-head; each entry also
records the chosen body per executed stage (``stage_bodies``), the
floor in effect (``min_bucket``/``derived_min_bucket``), and whether
the drift monitor flagged recalibration (``recalibration_due``) — the
bench explains its own numbers (docs/tuning.md §Observability).

Measured: filter-evaluation throughput vs N, N in 1..64; staged-vs-
exhaustive filter time and row-compaction speedup at N >= 16, recorded in
results/bench/multi_query_adaptive.json.

    PYTHONPATH=src python -m benchmarks.multi_query_sharing [--smoke]

``--smoke`` runs only the adaptive comparison at N=16 with few repeats
(seconds) — the per-PR perf-trajectory record (``make bench-smoke``).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import device_topology, emit, save_result, timeit
from repro.core import costmodel
from repro.core import query as Q
from repro.core.cascade import MultiQueryCascade
from repro.core.filters import FilterOutputs
from repro.core.plan import QueryPlan
from repro.core.stats import SlotStats

B, G, C = 64, 16, 8
SIZES = (1, 2, 4, 8, 16, 32, 64)
ADAPTIVE_SIZES = (16, 32, 64)


def _leaf_pool():
    """A realistic shared vocabulary: per-class counts, ordering between
    the scene's main actors, and a few watched regions."""
    pool = []
    for c in range(C):
        pool.append(Q.ClassCount(c, Q.Op.GE, 1))
        pool.append(Q.ClassCount(c, Q.Op.GE, 3, tolerance=1))
    for a, b in [(0, 1), (1, 2), (2, 3), (0, 4)]:
        pool.append(Q.Spatial(a, Q.Rel.LEFT, b))
        pool.append(Q.Spatial(a, Q.Rel.ABOVE, b, radius=1))   # CLF-1
        pool.append(Q.Spatial(b, Q.Rel.LEFT, a, radius=2))    # CLF-2
    for c in (0, 1, 2):
        pool.append(Q.Region(c, (0, 0, G // 2, G), 1))
        pool.append(Q.Region(c, (G // 2, 0, G, G), 2, radius=1))
    pool.append(Q.Count(Q.Op.GE, 4))
    pool.append(Q.Count(Q.Op.LE, 10, tolerance=2))
    return pool


def make_queries(n: int, seed: int = 0):
    pool = _leaf_pool()
    rng = np.random.default_rng(seed)
    queries = []
    for _ in range(n):
        k = int(rng.integers(2, 5))
        terms = [pool[j] for j in rng.choice(len(pool), k, replace=False)]
        if rng.random() < 0.3:
            terms[0] = Q.Not(terms[0])
        queries.append(Q.And(tuple(terms)) if rng.random() < 0.6
                       else Q.Or(tuple(terms)))
    return queries


def _time_serial(fns, out, repeat: int = 7) -> float:
    """Median us for dispatching every per-query program once."""
    for f in fns:                                    # warm the jit caches
        jax.block_until_ready(f(out))
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        for f in fns:
            jax.block_until_ready(f(out))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def run_sharing() -> dict:
    rng = np.random.default_rng(42)
    out = FilterOutputs(
        counts=jnp.asarray(rng.normal(2, 2, (B, C)).astype(np.float32)),
        grid=jnp.asarray(rng.normal(0, 0.7, (B, G, G, C)).astype(np.float32)))

    all_queries = make_queries(max(SIZES))
    # one compiled program per query — shared across the N sweep (a serial
    # engine keeps per-query programs; registrations don't recompile peers)
    serial_fns = [jax.jit(lambda o, q=q: Q.eval_filters(q, o))
                  for q in all_queries]

    res = {}
    print(f"{'N':>4s} {'serial us':>10s} {'fused us':>9s} {'shared us':>10s} "
          f"{'speedup':>8s} {'share':>6s} {'frames/s':>10s}")
    for n in SIZES:
        queries = all_queries[:n]
        plan = QueryPlan(queries)
        shared = jax.jit(plan.evaluate)
        fused = jax.jit(lambda o: jnp.stack(
            [Q.eval_filters(q, o) for q in queries], axis=1))
        want = np.stack([np.asarray(f(out)) for f in serial_fns[:n]], axis=1)
        np.testing.assert_array_equal(          # sharing is semantics-free
            np.asarray(shared(out)), want)

        us_serial = _time_serial(serial_fns[:n], out)
        us_fused = timeit(fused, out, repeat=7)
        us_shared = timeit(shared, out, repeat=7)
        speedup = us_serial / us_shared
        fps = B / (us_shared / 1e6)
        res[f"N{n}"] = {"us_serial": us_serial, "us_serial_fused": us_fused,
                        "us_shared": us_shared, "speedup": speedup,
                        "sharing_factor": plan.sharing_factor,
                        "frames_per_s": fps}
        emit(f"multi_query_sharing/N{n}", us_shared,
             f"speedup={speedup:.2f}x;share={plan.sharing_factor:.2f}")
        print(f"{n:4d} {us_serial:10.0f} {us_fused:9.0f} {us_shared:10.0f} "
              f"{speedup:7.2f}x {plan.sharing_factor:6.2f} {fps:10.0f}")

    res["device_topology"] = device_topology()
    save_result("multi_query_sharing", res)
    return res


# --------------------------------------------------------------------------
# staged adaptive vs exhaustive shared plan (ISSUE 2 acceptance)
# --------------------------------------------------------------------------

def make_skewed_queries(n: int, seed: int = 1):
    """And-dominated monitors guarded by a rarely-true count condition.

    Most registered alerts look like "when the scene is unusually busy
    AND <expensive spatial condition>" — the guard decides the query at
    the count tier almost every frame, so the spatial/SAT work is pure
    waste for an exhaustive evaluator.  A few always-true Or guards are
    mixed in so decided-true propagation is exercised too."""
    rng = np.random.default_rng(seed)
    queries = []
    for i in range(n):
        c = int(rng.integers(0, C))
        guard = Q.ClassCount(c, Q.Op.GE, 40)             # ~never true
        tail = [Q.Spatial(int(rng.integers(0, C)), Q.Rel.LEFT,
                          int(rng.integers(0, C)), radius=int(i % 3)),
                Q.Region(int(rng.integers(0, C)),
                         (0, 0, G // 2 + int(rng.integers(0, G // 2)), G),
                         1, radius=int(rng.integers(0, 3)))]
        if i % 5 == 4:        # Or guard that is ~always true
            queries.append(Q.Or((Q.Count(Q.Op.GE, 0), tail[0], tail[1])))
        else:
            queries.append(Q.And((guard, *tail)))
    return queries


def make_rowskewed_queries(n: int, seed: int = 2):
    """Row-skewed monitors: one shared busy-scene guard, ~10% selective.

    Every And alert gates on the same "unusually busy" total-count guard
    (realistic: alerts fire on the same traffic surges), so ~90% of each
    batch's ROWS are decided at the count tier while the spatial/SAT
    tiers still run for the busy remainder — the workload where
    tier-granular skipping buys nothing and row compaction is the whole
    win."""
    rng = np.random.default_rng(seed)
    busy = Q.Count(Q.Op.GE, 24)                   # ~10% of frames
    queries = []
    for i in range(n):
        tail = [Q.Spatial(int(rng.integers(0, C)), Q.Rel.LEFT,
                          int(rng.integers(0, C)), radius=int(i % 3)),
                Q.Region(int(rng.integers(0, C)),
                         (0, 0, G // 2 + int(rng.integers(0, G // 2)), G),
                         1, radius=int(rng.integers(0, 3)))]
        if i % 5 == 4:        # Or guard that is ~always true
            queries.append(Q.Or((Q.Count(Q.Op.GE, 0), tail[0], tail[1])))
        else:
            queries.append(Q.And((busy, *tail)))
    return queries


# row compaction amortizes per-stage dispatch over the batch: measure the
# rowskew workload at production batch size (the regime it targets)
B_ROWSKEW = 256


def _measure_staged(queries, out, repeat: int, warm_batches: int = 4,
                    min_bucket=None, measure_exhaustive: bool = True,
                    cost_model=None, spatial_body: str = "auto"):
    """(us_exhaustive, us_staged, staged) with warmed stats + restage.

    ``measure_exhaustive=False`` skips timing the exhaustive program
    (returns None for it) — the baseline calls reuse the exhaustive
    number already measured on the same queries/batch.  ``min_bucket``
    follows the engine's precedence: None derives the floor from the
    cost model; an explicit value pins it (the PR 4 baseline pins 8).
    ``spatial_body`` forces a compacted-spatial body ("rows" reproduces
    the PR 4 executor's hard-wired kernel choice)."""
    plan = QueryPlan(queries)
    exhaustive = jax.jit(plan.evaluate)
    stats = SlotStats()
    staged = plan.build_staged(stats, min_bucket=min_bucket,
                               cost_model=cost_model,
                               spatial_body=spatial_body)
    for _ in range(warm_batches):                 # learn population rates
        staged.evaluate(out)
        staged.flush_stats(stats)
    staged.restage(stats)
    np.testing.assert_array_equal(               # staging is semantics-free
        np.asarray(staged.evaluate(out)), np.asarray(exhaustive(out)))
    us_ex = (timeit(exhaustive, out, repeat=repeat)
             if measure_exhaustive else None)
    us_staged = timeit(staged.evaluate, out, repeat=repeat)
    return us_ex, us_staged, staged


def run_adaptive(smoke: bool = False) -> dict:
    sizes = (16,) if smoke else ADAPTIVE_SIZES
    repeat = 3 if smoke else 7
    rng = np.random.default_rng(42)
    # which cost model prices the staging decisions in this run: the
    # measured per-backend calibration when results/calibration/ holds a
    # trustworthy one (make calibrate), else the static fallback — each
    # JSON entry records it so the perf trajectory stays interpretable
    # across boxes and calibration states
    cm = costmodel.default_cost_model()
    print(f"cost model: {cm.source} (backend={cm.backend})")

    def rand_out(batch):
        return FilterOutputs(
            counts=jnp.asarray(rng.normal(2, 2,
                                          (batch, C)).astype(np.float32)),
            grid=jnp.asarray(rng.normal(0, 0.7,
                                        (batch, G, G, C)).astype(np.float32)))

    out64 = rand_out(B)
    out_rowskew = rand_out(B_ROWSKEW)

    res = {}
    print(f"{'workload':>10s} {'N':>4s} {'exhaustive us':>14s} "
          f"{'staged us':>10s} {'speedup':>8s} {'tieronly us':>12s} "
          f"{'rowspeed':>9s} {'rowsbody us':>11s} {'xover':>8s} "
          f"{'cascade us':>11s} {'mode':>11s} {'stages':>8s}")
    for workload, make in (("skewed", make_skewed_queries),
                           ("rowskew", make_rowskewed_queries),
                           ("uniform", make_queries)):
        out = out_rowskew if workload == "rowskew" else out64
        for n in sizes:
            queries = make(n)
            us_ex, us_staged, staged = _measure_staged(
                queries, out, repeat=repeat, cost_model=cm)
            report = staged.last_report
            # PR 2's tier-granular executor on the SAME queries/batch:
            # min_bucket >= B disables row compaction, so needed stages
            # run full-batch — the baseline row_compaction_speedup is
            # measured against
            _, us_tier_only, _ = _measure_staged(
                queries, out, repeat=repeat, min_bucket=1 << 30,
                measure_exhaustive=False, cost_model=cm)
            # PR 4's executor on the SAME queries/batch: compacted ⇒ row
            # kernel hard-wired, hand-set floor 8 — the baseline the
            # crossover-aware executor must never lose to
            _, us_rows_body, _ = _measure_staged(
                queries, out, repeat=repeat, min_bucket=8,
                measure_exhaustive=False, cost_model=cm,
                spatial_body="rows")
            speedup = us_ex / us_staged
            row_speedup = us_tier_only / us_staged
            crossover_speedup = us_rows_body / us_staged
            # the full adaptive cascade: staging + cost-model mode switch
            # (parks staging when the workload gives it nothing to skip)
            mqc = MultiQueryCascade(queries, adaptive=True, restage_every=8,
                                    cost_model=cm)
            for _ in range(2 * mqc.restage_every):          # learn + decide
                jax.block_until_ready(mqc.masks(out))
            mode = mqc.mode
            # freeze the decided mode: no restage boundary (and no staged
            # probe batch) may land inside the timed window, or the JSON
            # would blend two code paths under one label
            mqc.restage_every = 1 << 30
            us_casc = timeit(mqc.masks, out, repeat=repeat)
            monitor = mqc.calibration_monitor
            res[f"{workload}/N{n}"] = {
                "us_exhaustive": us_ex, "us_staged": us_staged,
                "speedup": speedup,
                "us_staged_tier_only": us_tier_only,    # PR 2 executor
                "row_compaction_speedup": row_speedup,
                "us_staged_rows_body": us_rows_body,    # PR 4 executor
                "crossover_speedup": crossover_speedup,
                "us_cascade": us_casc,
                "cascade_speedup": us_ex / us_casc, "cascade_mode": mode,
                "stages_run": len(report.ran),          # counts (ints) for
                "stages_skipped": len(report.skipped),  # trajectory diffs
                "stages_ran_names": report.ran,
                "stages_skipped_names": report.skipped,
                "rows_evaluated": report.rows_evaluated,
                "undecided_rows_in": report.undecided_rows_in,
                # which body ran each executed stage ("batch"/"rows"/
                # "full") — the crossover decision, self-explained
                "stage_bodies": report.bodies,
                "batch": report.batch,
                # the floor in effect and its derivation source
                "min_bucket": staged.min_bucket,
                "min_bucket_derived": staged.min_bucket_derived,
                "derived_min_bucket": cm.derived_min_bucket(),
                # did the drift monitor flag a recalibration during the
                # cascade run? (measured models only)
                "recalibration_due": mqc.recalibration_due,
                "calibration_monitor": (monitor.describe()
                                        if monitor is not None else None),
                # provenance: measured calibration vs static fallback
                "calibration": cm.source,
                "calibration_backend": cm.backend}
            emit(f"multi_query_adaptive/{workload}/N{n}", us_staged,
                 f"speedup={speedup:.2f}x;rows={row_speedup:.2f}x;"
                 f"xover={crossover_speedup:.2f}x;"
                 f"ran={len(report.ran)}/{len(report.order)};mode={mode}")
            print(f"{workload:>10s} {n:4d} {us_ex:14.0f} {us_staged:10.0f} "
                  f"{speedup:7.2f}x {us_tier_only:12.0f} {row_speedup:8.2f}x "
                  f"{us_rows_body:11.0f} {crossover_speedup:8.2f}x "
                  f"{us_casc:11.0f} {mode:>11s} "
                  f"{len(report.ran)}/{len(report.order)} ran "
                  f"bodies={','.join(report.bodies)}")

    res["calibration_info"] = cm.describe()
    res["device_topology"] = device_topology()
    save_result("multi_query_adaptive", res)
    return res


def run_temporal(smoke: bool = False) -> dict:
    """Temporal tier: window-outcome short-circuiting on a synthetic
    stream (``multi_query_temporal`` in the JSON; schema notes in
    docs/architecture.md §temporal).

    All-temporal workload whose queries decide their hopping-window
    outcome early — latching operators latch, an unreachable Duration
    dies — so the ``TemporalEngine`` suppresses decided signals
    (``signal_evals_skipped``), then skips whole batches once every
    query is decided (``frames_skipped``: no filter head, no plan, no
    oracle for those frames).  The baseline is the SAME engine with
    decidedness disabled (``_update_decidedness`` stubbed out): answers
    are bit-identical — the automata still latch — but nothing is ever
    skipped, so the delta is pure short-circuit win."""
    from repro.core.streaming import HoppingWindow
    from repro.core.temporal import TemporalEngine
    from repro.data.synthetic import PRESETS, VideoStream, collect

    n_frames = 512 if smoke else 2048
    cfg = PRESETS["detrac-like"]
    data = collect(VideoStream(cfg), n_frames)
    counts = jnp.asarray(data["counts"].astype(np.float32))
    grid = jnp.asarray(data["occupancy"].astype(np.float32))
    objects = data["objects"]

    def filter_fn(idx):
        idx = jnp.asarray(np.asarray(idx))
        return FilterOutputs(counts=counts[idx], grid=grid[idx])

    def oracle_fn(idx, sel):
        idx = np.asarray(idx)
        return [objects[int(idx[s])] for s in np.asarray(sel)]

    c0 = Q.ClassCount(0, Q.Op.GE, 1)
    c1 = Q.ClassCount(1, Q.Op.GE, 1)
    queries = [
        Q.Duration(c0, 4),                      # latches within frames
        Q.Duration(Q.ClassCount(2, Q.Op.GE, 6), 60),  # dies on 1st miss
        Q.SlidingCount(Q.Count(Q.Op.GE, 1), 8, Q.Op.GE, 1),
        Q.Sequence(c0, c1, 6),
        Q.And((Q.Duration(c0, 2), Q.SlidingCount(c1, 4, Q.Op.GE, 1))),
        # decides mid-window (a 40-run of a busy class-2 scene is needed;
        # a short run at the 32-frame boundary makes the remainder
        # infeasible): keeps early batches in the partial regime, where
        # the five queries above are decided and their signals
        # suppressed (signal_evals_skipped), before this one resolves
        # and the whole-batch skips kick in
        Q.Duration(Q.ClassCount(2, Q.Op.GE, 2), 40),
    ]
    window = HoppingWindow(size=64, advance=64)
    batch = 16

    def drive(engine):
        t0 = time.perf_counter()
        hits = np.zeros(len(queries), np.int64)
        for lo, hi in window.windows(n_frames):
            engine.on_window_start(lo, hi)
            for b0 in range(lo, hi, batch):
                out = engine(np.arange(b0, min(b0 + batch, hi)))
                hits += np.asarray(out).sum(0)
        return hits, (time.perf_counter() - t0) * 1e6 / n_frames

    def build():
        return TemporalEngine(queries, filter_fn, oracle_fn,
                              cfg.n_classes, cfg.grid)

    drive(build())                               # warm jit caches
    engine = build()
    hits, us_frame = drive(engine)
    base = build()
    base.program._update_decidedness = lambda: None   # short-circuit off
    base.program.start_window(0)                 # re-derive cold state
    hits_base, us_frame_base = drive(base)
    assert (hits == hits_base).all(), "short-circuit changed answers"
    st = engine.stats
    res = {
        "n_frames": n_frames, "windows": st.windows,
        "window_size": window.size, "batch": batch,
        "n_queries": len(queries),
        "frames_skipped_temporal": st.frames_skipped,
        "signal_evals_skipped": st.signal_evals_skipped,
        "oracle_frames": st.oracle_frames,
        "oracle_frames_baseline": base.stats.oracle_frames,
        "cost_saved_model": st.cost_saved_model,
        "us_per_frame": us_frame,
        "us_per_frame_no_shortcircuit": us_frame_base,
        "shortcircuit_speedup": us_frame_base / us_frame,
        "hits": [int(h) for h in hits],
    }
    emit("multi_query_temporal/detrac", us_frame,
         f"skipped={st.frames_skipped}/{n_frames};"
         f"sig_evals_skipped={st.signal_evals_skipped};"
         f"speedup={res['shortcircuit_speedup']:.2f}x")
    print(f"temporal: {st.frames_skipped}/{n_frames} frames skipped, "
          f"{st.signal_evals_skipped} signal evals suppressed, "
          f"{us_frame:.0f} us/frame vs {us_frame_base:.0f} baseline "
          f"({res['shortcircuit_speedup']:.2f}x)")
    res["device_topology"] = device_topology()
    save_result("multi_query_temporal", res)
    return res


def run() -> dict:
    res = {"sharing": run_sharing(), "adaptive": run_adaptive(),
           "temporal": run_temporal()}
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="adaptive comparison only, tiny budget (seconds); "
                         "still writes results/bench/multi_query_adaptive.json")
    args = ap.parse_args()
    if args.smoke:
        run_adaptive(smoke=True)
        run_temporal(smoke=True)
    else:
        run()


if __name__ == "__main__":
    main()
