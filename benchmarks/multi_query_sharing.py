"""Multi-query sharing: shared-plan evaluation vs per-query serial baseline.

A production monitor runs N concurrent queries over the same frames, and
the queries overlap heavily (everyone asks about the same few classes and
regions of the shared scene).  The serial baseline is what a
multi-query-unaware engine does: each registered query is its own compiled
program (``query.eval_filters``), dispatched and evaluated independently —
re-thresholding the CAM grid and re-scanning it per query, N times per
batch.  The shared engine (``core.plan.QueryPlan``) canonicalizes + dedups
the union of all leaves, evaluates each unique leaf once (counts: one
gather; Spatial: one fused (C, 5) stats reduction; Region: one summed-area
table per dilation radius) and reassembles per-query masks with incidence
einsums — one program for the whole query set.

We also report ``serial_fused`` — all N per-query evaluations traced into
a single XLA program, where CSE dedups identical leaves for free.  That is
an upper bound no serial engine reaches (it would recompile the whole
population on every registration), but it keeps us honest about how much
of the win is planning vs. mere fusion.

Measured: filter-evaluation throughput vs N, N in 1..64.
Acceptance target (ISSUE 1): >= 3x vs serial at N=16.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_result, timeit
from repro.core import query as Q
from repro.core.filters import FilterOutputs
from repro.core.plan import QueryPlan

B, G, C = 64, 16, 8
SIZES = (1, 2, 4, 8, 16, 32, 64)


def _leaf_pool():
    """A realistic shared vocabulary: per-class counts, ordering between
    the scene's main actors, and a few watched regions."""
    pool = []
    for c in range(C):
        pool.append(Q.ClassCount(c, Q.Op.GE, 1))
        pool.append(Q.ClassCount(c, Q.Op.GE, 3, tolerance=1))
    for a, b in [(0, 1), (1, 2), (2, 3), (0, 4)]:
        pool.append(Q.Spatial(a, Q.Rel.LEFT, b))
        pool.append(Q.Spatial(a, Q.Rel.ABOVE, b, radius=1))   # CLF-1
        pool.append(Q.Spatial(b, Q.Rel.LEFT, a, radius=2))    # CLF-2
    for c in (0, 1, 2):
        pool.append(Q.Region(c, (0, 0, G // 2, G), 1))
        pool.append(Q.Region(c, (G // 2, 0, G, G), 2, radius=1))
    pool.append(Q.Count(Q.Op.GE, 4))
    pool.append(Q.Count(Q.Op.LE, 10, tolerance=2))
    return pool


def make_queries(n: int, seed: int = 0):
    pool = _leaf_pool()
    rng = np.random.default_rng(seed)
    queries = []
    for _ in range(n):
        k = int(rng.integers(2, 5))
        terms = [pool[j] for j in rng.choice(len(pool), k, replace=False)]
        if rng.random() < 0.3:
            terms[0] = Q.Not(terms[0])
        queries.append(Q.And(tuple(terms)) if rng.random() < 0.6
                       else Q.Or(tuple(terms)))
    return queries


def _time_serial(fns, out, repeat: int = 7) -> float:
    """Median us for dispatching every per-query program once."""
    for f in fns:                                    # warm the jit caches
        jax.block_until_ready(f(out))
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        for f in fns:
            jax.block_until_ready(f(out))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def run() -> dict:
    rng = np.random.default_rng(42)
    out = FilterOutputs(
        counts=jnp.asarray(rng.normal(2, 2, (B, C)).astype(np.float32)),
        grid=jnp.asarray(rng.normal(0, 0.7, (B, G, G, C)).astype(np.float32)))

    all_queries = make_queries(max(SIZES))
    # one compiled program per query — shared across the N sweep (a serial
    # engine keeps per-query programs; registrations don't recompile peers)
    serial_fns = [jax.jit(lambda o, q=q: Q.eval_filters(q, o))
                  for q in all_queries]

    res = {}
    print(f"{'N':>4s} {'serial us':>10s} {'fused us':>9s} {'shared us':>10s} "
          f"{'speedup':>8s} {'share':>6s} {'frames/s':>10s}")
    for n in SIZES:
        queries = all_queries[:n]
        plan = QueryPlan(queries)
        shared = jax.jit(plan.evaluate)
        fused = jax.jit(lambda o: jnp.stack(
            [Q.eval_filters(q, o) for q in queries], axis=1))
        want = np.stack([np.asarray(f(out)) for f in serial_fns[:n]], axis=1)
        np.testing.assert_array_equal(          # sharing is semantics-free
            np.asarray(shared(out)), want)

        us_serial = _time_serial(serial_fns[:n], out)
        us_fused = timeit(fused, out, repeat=7)
        us_shared = timeit(shared, out, repeat=7)
        speedup = us_serial / us_shared
        fps = B / (us_shared / 1e6)
        res[f"N{n}"] = {"us_serial": us_serial, "us_serial_fused": us_fused,
                        "us_shared": us_shared, "speedup": speedup,
                        "sharing_factor": plan.sharing_factor,
                        "frames_per_s": fps}
        emit(f"multi_query_sharing/N{n}", us_shared,
             f"speedup={speedup:.2f}x;share={plan.sharing_factor:.2f}")
        print(f"{n:4d} {us_serial:10.0f} {us_fused:9.0f} {us_shared:10.0f} "
              f"{speedup:7.2f}x {plan.sharing_factor:6.2f} {fps:10.0f}")

    save_result("multi_query_sharing", res)
    return res


if __name__ == "__main__":
    run()
