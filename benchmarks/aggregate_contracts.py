"""Error-bounded aggregate benchmark (PR 9 acceptance): adaptive
Thompson allocation + control variates vs uniform sampling, meeting the
SAME accuracy contract on a skewed-rate stream.

The scenario is the paper's monitoring burst: one stream segment runs
hot (a rush-hour chunk where the predicate fires ~45% of frames) while
the rest idles at ~1-2%.  Both configurations answer the same
``AggregateQuery(..., eps, confidence)`` over the same synthetic
streams; the adaptive engine additionally taps a noisy cheap-filter
verdict as a control variate.  Per trial we record the novel oracle
frames each configuration paid before its contract terminated; over the
trial sweep we record realized CI coverage (which must clear the
nominal confidence for the comparison to be apples-to-apples — a
cheaper estimator that misses coverage is just broken).

Acceptance pin: ``savings_ratio = uniform_oracle_mean /
adaptive_oracle_mean > 1`` with both coverages >= nominal minus the
binomial tolerance of the sweep.

Run:  PYTHONPATH=src python -m benchmarks.aggregate_contracts [--smoke]
JSON: results/bench/aggregate_contracts.json
"""
from __future__ import annotations

import argparse
import math
import time

N_FRAMES = 2000
N_CHUNKS = 8
RATES = (0.01, 0.01, 0.01, 0.01, 0.01, 0.45, 0.02, 0.02)
EPS = 0.1
CONFIDENCE = 0.95


def _stream(seed):
    import numpy as np
    rng = np.random.default_rng(seed)
    bounds = np.linspace(0, N_FRAMES, N_CHUNKS + 1).astype(int)
    y = np.zeros(N_FRAMES)
    for j in range(N_CHUNKS):
        m = bounds[j + 1] - bounds[j]
        y[bounds[j]:bounds[j + 1]] = (rng.random(m) < RATES[j])
    z = np.clip(y + rng.normal(0.0, 0.3, N_FRAMES), 0.0, 1.0)
    return y, z


def _trial(seed, adaptive):
    import numpy as np
    from repro.core import query as Q
    from repro.core.contracts import AggregateQuery, ContractExecutor
    y, z = _stream(seed)
    q = AggregateQuery(pred=Q.ClassCount(0, Q.Op.GE, 1), agg="count",
                      eps=EPS, confidence=CONFIDENCE)
    ex = ContractExecutor(
        q, lambda f: y[np.asarray(f)], N_FRAMES,
        verdict_fn=(lambda f: z[np.asarray(f)].reshape(-1, 1))
        if adaptive else None,
        n_chunks=N_CHUNKS,
        allocation="thompson" if adaptive else "uniform",
        cv="auto" if adaptive else "off", seed=seed + 7919)
    res = ex.run()
    truth = float(y.sum())
    return {"oracle": res.oracle_calls,
            "covered": bool(res.ci[0] - 1e-9 <= truth <= res.ci[1] + 1e-9),
            "met": res.terminated in ("contract", "census"),
            "err": res.estimate - truth,
            "cv_chunks": res.cv_chunks,
            "vr": res.variance_reduction}


def _sweep(trials, adaptive):
    import numpy as np
    rows = [_trial(s, adaptive) for s in range(trials)]
    return {"config": "adaptive" if adaptive else "uniform",
            "trials": trials,
            "oracle_mean": float(np.mean([r["oracle"] for r in rows])),
            "oracle_p90": float(np.percentile([r["oracle"] for r in rows],
                                              90)),
            "coverage": float(np.mean([r["covered"] for r in rows])),
            "contract_met": float(np.mean([r["met"] for r in rows])),
            "bias": float(np.mean([r["err"] for r in rows])),
            "mean_cv_chunks": float(np.mean([r["cv_chunks"]
                                             for r in rows])),
            "mean_variance_reduction": float(np.mean([r["vr"]
                                                      for r in rows]))}


def run(smoke: bool = False):
    from benchmarks.common import (budget, device_topology, emit,
                                   save_result)
    trials = 30 if smoke else budget(100, 250)
    print(f"aggregate contracts: n={N_FRAMES}, {N_CHUNKS} chunks, "
          f"hot-rate {max(RATES)} vs cold {min(RATES)}, "
          f"contract +-{EPS:.0%} @ {CONFIDENCE:.0%} x{trials} trials "
          f"(smoke={smoke})")
    t0 = time.time()
    ad = _sweep(trials, adaptive=True)
    un = _sweep(trials, adaptive=False)
    savings = un["oracle_mean"] / max(ad["oracle_mean"], 1e-9)
    tol = 2.6 * math.sqrt(CONFIDENCE * (1 - CONFIDENCE) / trials)
    floor = CONFIDENCE - tol

    payload = {"n_frames": N_FRAMES, "n_chunks": N_CHUNKS,
               "rates": list(RATES), "eps": EPS,
               "confidence": CONFIDENCE, "smoke": smoke,
               "adaptive": ad, "uniform": un,
               "savings_ratio": savings,
               "coverage_floor": floor,
               "wall_s": time.time() - t0,
               "device_topology": device_topology()}
    save_result("aggregate_contracts", payload)

    emit("aggregate_contracts/adaptive_oracle", ad["oracle_mean"],
         f"coverage={ad['coverage']:.3f};vr={ad['mean_variance_reduction']:.2f}")
    emit("aggregate_contracts/uniform_oracle", un["oracle_mean"],
         f"coverage={un['coverage']:.3f}")
    for r in (ad, un):
        print(f"{r['config']:>9}: oracle mean={r['oracle_mean']:7.1f} "
              f"p90={r['oracle_p90']:7.1f} | coverage={r['coverage']:.3f} "
              f"met={r['contract_met']:.3f} bias={r['bias']:+.2f}")
    print(f"savings ratio (uniform/adaptive oracle calls): {savings:.2f}x "
          f"| coverage floor {floor:.3f}")
    ok = (savings > 1.0 and ad["coverage"] >= floor
          and un["coverage"] >= floor)
    print(f"acceptance (adaptive meets the same contract with fewer "
          f"oracle calls, both at nominal coverage): "
          f"{'PASS' if ok else 'FAIL'}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale budget; still writes "
                         "results/bench/aggregate_contracts.json")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
