"""Paper Fig. 11: per-class count (CCF) accuracy across data sets.

Paper claims being checked:
- less popular classes get *higher* count accuracy (few objects per frame
  -> easier estimation problem), despite fewer training examples;
- IC-CCF has a slight edge on exact per-class counts.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import budget, cached_filter, emit, save_result
from repro.data.synthetic import PRESETS
from repro.models.config import BranchSpec
from repro.train.filter_train import evaluate_filter, train_filter


def run() -> dict:
    steps = budget(220, 1200)
    out = {}
    for scene_name in ("jackson-like", "detrac-like"):
        scene = PRESETS[scene_name]
        for kind in ("ic", "od"):
            tf = cached_filter(scene, kind, steps, budget(1500, 8000))
            res = evaluate_filter(tf, scene, n_frames=budget(400, 1500))
            row = {f"tol{t}": res[f"ccf_acc_{t}"].tolist()
                   for t in (0, 1, 2)}
            out[f"{scene_name}/{kind}"] = row
            emit(f"fig11/{scene_name}/{kind}", 0.0,
                 "acc0=" + "/".join(f"{a:.2f}" for a in row["tol0"]))
    save_result("fig11_ccf", out)

    print("\nFig.11 — per-class CCF accuracy (tol 0), classes ordered by "
          "frequency (class 0 most frequent)")
    for k, v in out.items():
        print(f"{k:28s} " + "  ".join(f"{a:.3f}" for a in v["tol0"]))
    return out


if __name__ == "__main__":
    run()
