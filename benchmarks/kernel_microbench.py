"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode, so
absolute kernel timings are meaningless; what IS meaningful here:

- allclose validation at benchmark shapes (kernel == oracle),
- executed-FLOPs + VMEM-tile accounting per kernel (the structural
  numbers a TPU deployment is judged by),
- XLA reference-path timings (the non-Pallas fallbacks we'd compare
  against on real hardware).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_result, timeit
from repro.kernels import ops, ref
from repro.models import layers as L


def run() -> dict:
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 8)
    out = {}

    # flash attention: XLA scan path timing + kernel flops accounting
    B, S, H, KV, hd = 2, 1024, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    xla_fn = jax.jit(lambda a, b, c: L.flash_attention_xla(
        a, b, c, causal=True, chunk=256, n_macro=4))
    us = timeit(xla_fn, q, k, v, repeat=3)
    flops = 2 * 2 * B * H * S * S * hd * 0.56     # macro-blocked causal
    out["flash_attention_xla_us"] = us
    out["flash_attention_gflops_per_call"] = flops / 1e9
    emit("kernel/flash_attention_xla", us,
         f"gflops={flops/1e9:.1f};vmem_tile=128x128x{hd}")

    o_pallas = ops.flash_attention(q[:1, :256], k[:1, :256], v[:1, :256])
    o_ref = ref.flash_attention_ref(q[:1, :256], k[:1, :256], v[:1, :256])
    assert float(jnp.max(jnp.abs(o_pallas - o_ref))) < 1e-4
    emit("kernel/flash_attention_allclose", 0.0, "ok")

    # decode attention
    qd = jax.random.normal(ks[3], (4, H, hd))
    kd = jax.random.normal(ks[4], (4, 2048, KV, hd))
    vd = jax.random.normal(ks[5], (4, 2048, KV, hd))
    dec_ref = jax.jit(ref.decode_attention_ref)
    us = timeit(dec_ref, qd, kd, vd, jnp.int32(2048), repeat=3)
    out["decode_attention_ref_us"] = us
    emit("kernel/decode_attention_xla", us,
         f"cache_bytes={kd.nbytes*2};vmem_tile=256x{hd}")
    np.testing.assert_allclose(
        ops.decode_attention(qd, kd, vd, jnp.int32(1500)),
        ref.decode_attention_ref(qd, kd, vd, jnp.int32(1500)), atol=1e-4)
    emit("kernel/decode_attention_allclose", 0.0, "ok")

    # cam head (the paper's 1.5ms/frame hot path)
    feat = jax.random.normal(ks[6], (8, 56, 56, 512))
    w = jax.random.normal(ks[7], (512, 128)) * 0.05
    b = jnp.zeros(128)
    cam_ref = jax.jit(ref.cam_head_ref)
    us = timeit(cam_ref, feat, w, b, repeat=3)
    flops = 2 * 8 * 56 * 56 * 512 * 128
    out["cam_head_ref_us"] = us
    emit("kernel/cam_head_xla", us,
         f"gflops={flops/1e9:.2f};vmem_acc=56*56x128xf32=1.6MB")
    c1, m1 = ops.cam_head(feat[:1], w, b)
    c2, m2 = ref.cam_head_ref(feat[:1], w, b)
    assert float(jnp.max(jnp.abs(m1 - m2))) < 1e-2
    emit("kernel/cam_head_allclose", 0.0, "ok")

    # spatial stats
    gl = jax.random.normal(ks[0], (64, 56, 56, 8)) * 3
    ss_ref = jax.jit(ref.spatial_stats_ref)
    us = timeit(ss_ref, gl, repeat=3)
    out["spatial_stats_ref_us"] = us
    emit("kernel/spatial_stats_xla", us, "out=64x8x5")
    np.testing.assert_allclose(ops.spatial_stats(gl[:4]),
                               ref.spatial_stats_ref(gl[:4]))
    emit("kernel/spatial_stats_allclose", 0.0, "ok")

    # rwkv6 chunked scan (model path) vs sequential oracle
    Bh, Hh, T, K = 2, 4, 512, 64
    r = jax.random.normal(ks[1], (Bh, Hh, T, K))
    kk = jax.random.normal(ks[2], (Bh, Hh, T, K))
    vv = jax.random.normal(ks[3], (Bh, Hh, T, K))
    lw = jnp.clip(-jnp.exp(jax.random.normal(ks[4], (Bh, Hh, T, K)) * 0.3),
                  -2.0, -1e-6)
    u = jax.random.normal(ks[5], (Hh, K)) * 0.1
    s0 = jnp.zeros((Bh, Hh, K, K))
    from repro.models.ssm import rwkv_chunk_scan
    chunk_fn = jax.jit(rwkv_chunk_scan)
    us = timeit(chunk_fn, r, kk, vv, lw, u, s0, repeat=3)
    out["rwkv6_chunked_us"] = us
    emit("kernel/rwkv6_chunked_xla", us, f"T={T};chunk=32")
    o1, _ = ops.rwkv6_scan(r[:1, :1, :64], kk[:1, :1, :64], vv[:1, :1, :64],
                           lw[:1, :1, :64], u[:1], s0[:1, :1])
    o2, _ = ref.rwkv6_scan_ref(r[:1, :1, :64], kk[:1, :1, :64],
                               vv[:1, :1, :64], lw[:1, :1, :64], u[:1],
                               s0[:1, :1])
    assert float(jnp.max(jnp.abs(o1 - o2))) < 5e-3
    emit("kernel/rwkv6_allclose", 0.0, "ok")

    save_result("kernel_microbench", out)
    return out


if __name__ == "__main__":
    run()
