"""Paper Table IV: control-variate variance reduction on aggregate queries.

Five aggregate query analogues (a1–a5): sampled frames are evaluated by
the oracle (Y) and by the trained filters (X / Z vector); the CV/MCV
estimator's variance reduction vs the naive sample mean is reported,
together with the per-sample cost increase (filter time on top of the
200 ms oracle — the paper reports 201.6–202.2 ms).
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import budget, cached_filter, emit, save_result
from repro.core import aggregates as AGG
from repro.core import query as Q
from repro.data.synthetic import PRESETS, VideoStream, collect
from repro.models.config import BranchSpec
from repro.train.filter_train import train_filter

ORACLE_MS = 200.0

AGGS = [
    # (name, scene, oracle-Y fn(objects)->float, filter-Z fns(fout,i)->[float])
    ("a1", "jackson-like",
     lambda objs, g: float(Q.eval_objects(
         Q.Region(0, (g // 2, g // 2, g, g)), objs, 2, g)),
     lambda fo, i, g: [float(Q.eval_filters(
         Q.Region(0, (g // 2, g // 2, g, g), radius=1), _row(fo, i))[0])]),
    ("a2", "jackson-like",
     lambda objs, g: float(Q.eval_objects(
         Q.Spatial(0, Q.Rel.LEFT, 1), objs, 2, g)),
     lambda fo, i, g: [
         float(Q.eval_filters(Q.Spatial(0, Q.Rel.LEFT, 1),
                              _row(fo, i))[0]),
         float(Q.eval_filters(Q.Spatial(0, Q.Rel.LEFT, 1, radius=1),
                              _row(fo, i))[0])]),
    ("a3", "detrac-like",
     lambda objs, g: float(len(objs) == 3),
     lambda fo, i, g: [float(Q.eval_filters(
         Q.Count(Q.Op.EQ, 3, tolerance=1), _row(fo, i))[0]),
         float(np.round(np.asarray(fo.counts[i]).sum()))]),
    ("a4", "detrac-like",
     lambda objs, g: float(Q.eval_objects(
         Q.Spatial(0, Q.Rel.LEFT, 1), objs, 3, g)),
     lambda fo, i, g: [
         float(Q.eval_filters(Q.Spatial(0, Q.Rel.LEFT, 1),
                              _row(fo, i))[0]),
         float(Q.eval_filters(Q.Spatial(0, Q.Rel.LEFT, 1, radius=1),
                              _row(fo, i))[0])]),
    ("a5", "coral-like",
     lambda objs, g: float(len(objs) >= 3 and Q.eval_objects(
         Q.Region(0, (g // 2, 0, g, g // 2), min_count=2), objs, 1, g)),
     lambda fo, i, g: [
         float(np.round(np.asarray(fo.counts[i]).sum())),
         float(Q.eval_filters(
             Q.Region(0, (g // 2, 0, g, g // 2), min_count=2, radius=1),
             _row(fo, i))[0])]),
]


def _row(fo, i):
    from repro.core.filters import FilterOutputs
    return FilterOutputs(counts=fo.counts[i:i + 1],
                         grid=fo.grid[i:i + 1])


def run() -> dict:
    steps = budget(250, 1200)
    n_frames = budget(1200, 6000)
    n_samples = budget(300, 2000)
    filters: Dict[str, object] = {}
    out = {}
    rng = np.random.default_rng(0)

    for name, scene_name, y_fn, z_fn in AGGS:
        scene = PRESETS[scene_name]
        if scene_name not in filters:
            filters[scene_name] = cached_filter(scene, "od", steps,
                                                budget(1500, 8000))
        tf = filters[scene_name]
        data = collect(VideoStream(scene), n_frames)
        fn = tf.jitted()

        t0 = time.perf_counter()
        fout = fn(tf.params, jnp.asarray(data["embeds"]))
        jax.block_until_ready(fout.counts)
        filter_ms = (time.perf_counter() - t0) / n_frames * 1e3

        idx = rng.choice(n_frames, size=n_samples, replace=False)
        g = scene.grid
        y = np.array([y_fn(data["objects"][i], g) for i in idx])
        Z = np.array([z_fn(fout, i, g) for i in idx], np.float64)
        if Z.ndim == 1:
            Z = Z[:, None]
        est = AGG.mcv_estimate(y, Z)
        naive_mean = float(y.mean())
        out[name] = {
            "scene": scene_name, "d_controls": Z.shape[1],
            "naive_mean": naive_mean, "cv_mean": est.mean,
            "variance_reduction": est.variance_reduction,
            "per_sample_ms": ORACLE_MS + filter_ms,
        }
        emit(f"table4/{name}", (ORACLE_MS + filter_ms) * 1e3,
             f"var_reduction={est.variance_reduction:.1f}x")

    save_result("table4_cv_variance", out)
    print("\nTable IV — CV variance reduction "
          "(per-sample cost = 200ms oracle + filter)")
    print(f"{'q':4s} {'controls':>8s} {'ms/sample':>10s} {'reduction':>10s}")
    for k, v in out.items():
        print(f"{k:4s} {v['d_controls']:8d} {v['per_sample_ms']:10.1f} "
              f"{v['variance_reduction']:9.1f}x")
    return out


if __name__ == "__main__":
    run()
