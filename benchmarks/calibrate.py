"""Calibrate the staged planner's cost model on the active backend.

Runs ``repro.core.costmodel.calibrate()``: microbenchmarks of the actual
stage bodies (count gather, full-batch + row-gathered spatial stats,
threshold+SAT region body, one dilation step) at several row counts,
plus the staged executor's per-stage propagation overhead, fitted to
``cost(rows) = overhead + per_row * rows`` per stage and written to
``results/calibration/<backend>.json`` with a backend fingerprint.  The
adaptive engine (``costmodel.default_cost_model()``) loads that file on
the next start — and falls back to the static constants whenever it is
missing, corrupt, stale, or fingerprinted for a different backend.

    PYTHONPATH=src python -m benchmarks.calibrate   # == make calibrate

On this CPU container the Pallas kernels run through their XLA fallback
paths, so the measured coefficients describe THIS box — which is the
point: each deployment calibrates where it runs.
"""
from __future__ import annotations

import argparse

from repro.core import costmodel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=256,
                    help="largest row count measured (power of two "
                         "sub-points are derived from it)")
    ap.add_argument("--grid", type=int, default=16)
    ap.add_argument("--classes", type=int, default=8)
    ap.add_argument("--repeat", type=int, default=3,
                    help="timing repeats per (body, rows) point (median)")
    ap.add_argument("--path", default=None,
                    help="output path (default: "
                         "results/calibration/<backend>.json)")
    ap.add_argument("--dry-run", action="store_true",
                    help="measure and print, write nothing")
    args = ap.parse_args()

    model = costmodel.calibrate(
        batch=args.batch, grid=args.grid, classes=args.classes,
        repeat=args.repeat, save=not args.dry_run, path=args.path)

    print(f"backend: {model.backend}   fingerprint: {model.fingerprint}")
    print(f"{'stage body':>14s} {'overhead us':>12s} {'per-row us':>11s}")
    for key in costmodel.STAGE_COEFF_KEYS:
        c = model.coeffs[key]
        print(f"{key:>14s} {c.overhead:12.1f} {c.per_row:11.3f}")
    print(f"{'step overhead':>14s} {model.step_overhead():12.1f}")
    # the two decisions this calibration derives (docs/tuning.md)
    names = {"rows": "row-gather kernel", "full": "full-batch body"}
    xover = model.spatial_crossover_rows()
    if xover is None:                  # no tie point: one winner everywhere
        desc = f"none ({names[model.spatial_body(rows=1)]} always wins)"
    else:
        below = names[model.spatial_body(rows=xover / 2)]
        above = names[model.spatial_body(rows=xover * 2)]
        desc = f"{xover:.1f} rows ({below} below, {above} above)"
    print(f"spatial-body crossover: {desc}")
    print(f"derived min_bucket: {model.derived_min_bucket()} "
          f"(hand-set default was 8; explicit min_bucket= still wins)")
    if not args.dry_run:
        path = args.path or costmodel.calibration_path(model.backend)
        print(f"\nwrote {path} — the adaptive engine loads it on the next "
              f"start (stale after "
              f"{costmodel.DEFAULT_MAX_AGE_S / 86400:.0f} days or any "
              f"backend/jax change; re-run `make calibrate` then)")


if __name__ == "__main__":
    main()
