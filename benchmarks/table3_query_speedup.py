"""Paper Table III: cascade execution time / speedup at matched accuracy.

Seven query analogues (q1–q7) over the Table-II-matched streams.  For each
query we progressively enable filter combinations (as the paper does) and
report the most selective combination reaching target recall, its
selectivity, and the resulting speedup vs annotating every frame with the
oracle.  The oracle cost is the paper's measured Mask R-CNN 200 ms/frame;
filter cost is OUR measured per-frame branch latency (so the speedup
combines the paper's cost model with our measured selectivity/accuracy).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import budget, cached_filter, emit, save_result
from repro.core import cascade as CS
from repro.core import query as Q
from repro.data.synthetic import PRESETS, VideoStream, collect
from repro.models.config import BranchSpec
from repro.train.filter_train import train_filter

ORACLE_MS = 200.0     # paper §IV: Mask R-CNN per frame

# q1..q7 analogues (paper §IV-B) — scene, query builder, tolerant variant
QUERIES = [
    ("q1", "coral-like",
     lambda: Q.ClassCount(0, Q.Op.EQ, 2),
     lambda: Q.ClassCount(0, Q.Op.EQ, 2, tolerance=1)),
    ("q2", "coral-like",
     lambda: Q.And((Q.ClassCount(0, Q.Op.EQ, 2),
                    Q.Region(0, (4, 0, 8, 4)))),
     lambda: Q.And((Q.ClassCount(0, Q.Op.EQ, 2, tolerance=1),
                    Q.Region(0, (4, 0, 8, 4), radius=1)))),
    ("q3", "jackson-like",
     lambda: Q.And((Q.ClassCount(0, Q.Op.EQ, 1),
                    Q.ClassCount(1, Q.Op.EQ, 1))),
     lambda: Q.And((Q.ClassCount(0, Q.Op.EQ, 1, tolerance=1),
                    Q.ClassCount(1, Q.Op.EQ, 1, tolerance=1)))),
    ("q4", "jackson-like",
     lambda: Q.And((Q.ClassCount(0, Q.Op.GE, 1),
                    Q.ClassCount(1, Q.Op.GE, 1))),
     lambda: Q.And((Q.ClassCount(0, Q.Op.GE, 1, tolerance=1),
                    Q.ClassCount(1, Q.Op.GE, 1, tolerance=1)))),
    ("q5", "jackson-like",
     lambda: Q.And((Q.ClassCount(0, Q.Op.EQ, 1),
                    Q.ClassCount(1, Q.Op.EQ, 1),
                    Q.Spatial(0, Q.Rel.LEFT, 1))),
     lambda: Q.And((Q.ClassCount(0, Q.Op.EQ, 1, tolerance=1),
                    Q.ClassCount(1, Q.Op.EQ, 1, tolerance=1),
                    Q.Spatial(0, Q.Rel.LEFT, 1, radius=1)))),
    # q6/q7 constants calibrated to the detrac-like base rates (15.8
    # objects/frame, class mix 92/6/2): "exactly one bus among >= 10 cars"
    # has the paper-query character (rare conjunctive event) with a
    # non-empty answer set on the synthetic stream.
    ("q6", "detrac-like",
     lambda: Q.And((Q.ClassCount(1, Q.Op.EQ, 1),
                    Q.ClassCount(0, Q.Op.GE, 10))),
     lambda: Q.And((Q.ClassCount(1, Q.Op.EQ, 1, tolerance=1),
                    Q.ClassCount(0, Q.Op.GE, 10, tolerance=2)))),
    ("q7", "detrac-like",
     lambda: Q.And((Q.ClassCount(1, Q.Op.EQ, 1),
                    Q.ClassCount(0, Q.Op.GE, 10),
                    Q.Spatial(0, Q.Rel.LEFT, 1))),
     lambda: Q.And((Q.ClassCount(1, Q.Op.EQ, 1, tolerance=2),
                    Q.ClassCount(0, Q.Op.GE, 10, tolerance=3),
                    Q.Spatial(0, Q.Rel.LEFT, 1, radius=2)))),
]


def run() -> dict:
    steps = budget(250, 1200)
    n_frames = budget(1024, 8000)
    filters = {}
    out: Dict[str, dict] = {}

    for name, scene_name, strict_q, tolerant_q in QUERIES:
        scene = PRESETS[scene_name]
        if scene_name not in filters:
            filters[scene_name] = cached_filter(scene, "od", steps,
                                                budget(1500, 8000))
        tf = filters[scene_name]
        data = collect(VideoStream(scene), n_frames)
        fn = tf.jitted()

        # measure per-frame filter latency (batched)
        emb = jnp.asarray(data["embeds"][:64])
        fn(tf.params, emb).counts.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            fn(tf.params, emb).counts.block_until_ready()
        filter_ms = (time.perf_counter() - t0) / 3 / 64 * 1e3

        truth = np.array([Q.eval_objects(strict_q(), o, scene.n_classes,
                                         scene.grid)
                          for o in data["objects"]])
        fout = fn(tf.params, jnp.asarray(data["embeds"]))

        best = None
        for variant, qv in (("strict", strict_q()),
                            ("tolerant", tolerant_q())):
            mask = np.asarray(Q.eval_filters(qv, fout))
            # oracle-exact answers on survivors
            answers = np.zeros(len(truth), bool)
            idx = np.nonzero(mask)[0]
            for j in idx:
                answers[j] = truth[j]
            tp = int((answers & truth).sum())
            recall = tp / max(int(truth.sum()), 1)
            sel = mask.mean()
            t_full = len(truth) * ORACLE_MS
            t_ours = len(truth) * filter_ms + idx.size * ORACLE_MS
            row = {"variant": variant, "recall": recall,
                   "selectivity": float(sel),
                   "speedup": t_full / t_ours,
                   "filter_ms": filter_ms,
                   "positives": int(truth.sum())}
            if best is None or (row["recall"] >= 0.99 >
                                best["recall"]) or \
                    (row["recall"] >= 0.99 and best["recall"] >= 0.99 and
                     row["speedup"] > best["speedup"]):
                best = row
            if row["recall"] >= 0.999:
                break
        out[name] = best
        emit(f"table3/{name}", best["filter_ms"] * 1e3,
             f"recall={best['recall']:.3f};speedup={best['speedup']:.1f}x;"
             f"sel={best['selectivity']:.3f}")

    save_result("table3_query_speedup", out)
    print("\nTable III — query cascade (oracle 200ms/frame, our filters)")
    print(f"{'q':4s} {'variant':9s} {'recall':>7s} {'select':>7s} "
          f"{'speedup':>9s}")
    for k, v in out.items():
        print(f"{k:4s} {v['variant']:9s} {v['recall']:7.3f} "
              f"{v['selectivity']:7.3f} {v['speedup']:8.1f}x")
    return out


if __name__ == "__main__":
    run()
