"""Roofline analysis over dry-run artifacts (deliverable g).

Reads results/dryrun/*.json and emits the per-(arch x shape x mesh) table:
compute / memory / collective terms (seconds), dominant bottleneck,
MODEL_FLOPS = 6·N·D (train) or 2·N·D (serve) with N_active for MoE, and
the useful-FLOPs fraction.  Markdown output is pasted into
EXPERIMENTS.md §Roofline.

CPU-backend caveat (recorded here once, applies to every row): XLA:CPU
reports ``bytes accessed`` without TPU-grade fusion, so the memory term is
an *upper bound* — TPU compilations fuse elementwise chains that CPU
counts as separate HBM round trips.  FLOPs and collective bytes are
fusion-independent and transfer directly.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir: str, tag: str = "") -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        name = os.path.basename(p)[:-5]
        parts = name.split("__")
        r["_tag"] = parts[3] if len(parts) > 3 else ""
        if r["_tag"] != tag:
            continue
        recs.append(r)
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]),
                             r["mesh"]))
    return recs


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def table(recs: List[Dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute | memory | collective | bottleneck | "
        "peak GB/dev | fits | useful-FLOPs frac | step tokens |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if not r.get("status", "").startswith("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status'][:60]} | — | — | — | — |")
            continue
        t = r["roofline"]
        m = r["memory"]
        # peak_bytes is XLA's own peak estimate and accounts for donation
        # aliasing (state-in aliases state-out); the arg+temp+out sum would
        # double-count donated buffers.
        peak = (m["peak_bytes"] or
                (m["argument_bytes"] + m["temp_bytes"] + m["output_bytes"]))
        fits = peak < 16 * 2 ** 30 if m["peak_bytes"] else m["fits_hbm"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"**{t['bottleneck']}** | {peak/2**30:.2f} | "
            f"{'Y' if fits else 'N'} | "
            f"{t['useful_flops_frac']:.2f} | {t['tokens']:,} |")
    return "\n".join(lines)


def pick_hillclimb(recs: List[Dict]) -> List[Dict]:
    """worst useful-FLOPs fraction, most collective-bound, most
    paper-representative (decode gating cell of the flagship oracle)."""
    ok = [r for r in recs if r.get("status") == "ok" and
          r["mesh"] == "single"]
    worst = min((r for r in ok if r["shape"] == "train_4k"),
                key=lambda r: r["roofline"]["useful_flops_frac"])
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"])
    return [worst, coll]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(args.dir, args.tag)
    print(f"## Roofline table — {args.mesh} pod mesh "
          f"({256 if args.mesh=='single' else 512} chips)\n")
    print(table(recs, args.mesh))
    ok = [r for r in recs if r.get("status") == "ok"]
    n_skip = len(recs) - len(ok)
    print(f"\n{len(ok)} compiled cells, {n_skip} documented skips.")
    picks = pick_hillclimb(recs)
    print("\nhillclimb candidates:",
          [(r["arch"], r["shape"]) for r in picks])


if __name__ == "__main__":
    main()
