"""Query-churn benchmark (PR 8 acceptance): registration-to-first-result
latency and steady-state throughput under Poisson query arrival/retirement.

The interactive-workload stress the incremental plan lifecycle exists
for: a pool of query templates arrives and retires mid-stream (Poisson
event counts per batch interval), and every registry epoch forces an
engine rebuild at the next batch boundary.  Two configurations answer
the same churn trace over the same synthetic stream:

  baseline      every rebuild re-canonicalizes into a FRESH leaf table
                and re-jits into a FRESH private step cache — the
                pre-refactor lifecycle, where one registration stalls
                all resident queries behind recompiles.
  incremental   rebuilds share the registry-owned ``CanonicalLeafTable``
                (stable slot ids, tombstoned retirements) and
                ``StepCache`` (content-signature step keys), and arrival
                bursts coalesce through ``QueryRegistry.batch()`` — a
                rebuild whose distinct-template set recurred (duplicate
                registrations included) re-hits every compiled step.

Per churn event we record **registration-to-first-result latency**: the
wall time from applying the registry mutation to the first batch of
answers produced by the rebuilt engine (plan build + staging + any
compiles + the batch itself).  Steady state is reached once the churn
trace revisits step signatures it has compiled before; the acceptance
pin is ``steady_state_compiles == 0`` for the incremental
configuration — a step whose content signature was compiled once is
never traced again, while the baseline re-traces every resident step
on every rebuild — with the p50/p99 latency improvement and
steady-state fps recorded alongside.

Run:  PYTHONPATH=src python -m benchmarks.query_churn [--smoke]
JSON: results/bench/query_churn.json
"""
from __future__ import annotations

import argparse
import time

BATCH, C, G = 32, 6, 8
TAU = 0.2
ARRIVAL_RATE = 0.8          # Poisson mean arrivals per batch interval
RETIRE_RATE = 0.6           # Poisson mean retirements per batch interval


def _template_pool():
    from repro.core import query as Q
    return (
        Q.And((Q.ClassCount(0, Q.Op.GE, 3), Q.Spatial(0, Q.Rel.LEFT, 1))),
        Q.ClassCount(1, Q.Op.LE, 1),
        Q.Or((Q.Count(Q.Op.GE, 10), Q.Region(2, (0, 0, 4, 4), 1))),
        Q.Not(Q.ClassCount(2, Q.Op.GE, 2)),
        Q.And((Q.Region(1, (2, 2, 6, 6), 1, 1),
               Q.ClassCount(3, Q.Op.GE, 1))),
        Q.Or((Q.Spatial(2, Q.Rel.ABOVE, 3), Q.Count(Q.Op.LE, 4))),
    )


def _stream_data(n_frames):
    import jax.numpy as jnp
    import numpy as np
    r = np.random.default_rng(11)
    return (jnp.asarray(r.poisson(0.5, (n_frames, C)).astype(np.float32)),
            jnp.asarray((r.random((n_frames, G, G, C)) < 0.05)
                        .astype(np.float32)))


def _churn_trace(n_batches, seed=17):
    """Deterministic Poisson arrival/retirement schedule over the pool:
    per batch, a list of ('register', template_idx) / ('retire',) events.
    Both configurations replay the identical trace."""
    import numpy as np
    r = np.random.default_rng(seed)
    pool_n = len(_template_pool())
    trace = []
    for _ in range(n_batches):
        events = []
        for _ in range(r.poisson(ARRIVAL_RATE)):
            events.append(("register", int(r.integers(0, pool_n))))
        for _ in range(r.poisson(RETIRE_RATE)):
            events.append(("retire",))
        trace.append(events)
    return trace


def _run_config(incremental: bool, n_batches: int) -> dict:
    import numpy as np
    from repro.core import costmodel as CM
    from repro.core.filters import FilterOutputs
    from repro.core.plan import QueryPlan
    from repro.core.streaming import QueryRegistry

    pool = _template_pool()
    counts, grid = _stream_data(n_batches * BATCH)
    cm = CM.static_cost_model()
    registry = QueryRegistry()
    trace = _churn_trace(n_batches)

    # resident floor: two templates always live, so the engine never
    # empties and retirements always have something to take
    floor = [registry.register(pool[0]), registry.register(pool[1])]
    retirable: list = []

    def build_engine(queries):
        kw = {}
        if incremental:
            kw["leaf_table"] = registry.leaf_table
        plan = QueryPlan(queries, tau=TAU, **kw)
        staged = plan.build_staged(
            None, cost_model=cm,
            step_cache=registry.step_cache if incremental else None)
        return plan, staged

    epoch = -1
    plan = staged = None
    seen_sigs: set = set()      # plan signatures already built once
    seen_keys: set = set()      # step signatures already compiled once
    reg_latencies = []          # registration -> first batch of answers
    redundant_compiles = 0      # traces for an already-seen step signature
    steady_rebuilds = 0
    rebuilds = 0
    total_traces = 0
    frames = 0
    t_stream = 0.0

    def run_batch(out):
        """Evaluate one batch; return traces paid and how many of them
        re-compiled a step signature compiled earlier in the run."""
        before = staged._trace_count
        np.asarray(staged.evaluate(out))
        dt = staged._trace_count - before
        new = [k for k in staged.step_cache.keys() if k not in seen_keys]
        seen_keys.update(new)
        return dt, dt - min(dt, len(new))

    for b, events in enumerate(trace):
        t_churn = None
        if events:
            t_churn = time.perf_counter()
            ctx = registry.batch() if incremental else None
            if ctx is not None:
                ctx.__enter__()
            for ev in events:
                if ev[0] == "register":
                    retirable.append(registry.register(pool[ev[1]]))
                elif retirable:
                    registry.retire(retirable.pop(0))
            if ctx is not None:
                ctx.__exit__(None, None, None)
        idx = np.arange(b * BATCH, (b + 1) * BATCH)
        out = FilterOutputs(counts=counts[idx], grid=grid[idx])
        t0 = time.perf_counter()
        if registry.epoch != epoch:
            queries = tuple(q for _, q in registry.active())
            plan, staged = build_engine(queries)
            if plan.plan_sig in seen_sigs:
                steady_rebuilds += 1
            seen_sigs.add(plan.plan_sig)
            dt, redo = run_batch(out)               # first answers
            total_traces += dt
            redundant_compiles += redo
            rebuilds += 1
            epoch = registry.epoch
            if t_churn is not None:
                reg_latencies.append(time.perf_counter() - t_churn)
        else:
            dt, redo = run_batch(out)
            total_traces += dt
            redundant_compiles += redo
        t_stream += time.perf_counter() - t0
        frames += BATCH

    lat = np.sort(np.asarray(reg_latencies))

    def pct(p):
        if not lat.size:
            return None
        return float(lat[min(int(round(p / 100 * (lat.size - 1))),
                             lat.size - 1)]) * 1e3

    res = {"config": "incremental" if incremental else "baseline",
           "batches": n_batches, "frames": frames,
           "churn_events": int(sum(len(e) for e in trace)),
           "rebuilds": rebuilds,
           "rebuilds_on_recurring_sig": steady_rebuilds,
           "steady_state_compiles": redundant_compiles,
           "total_steps_compiled": total_traces,
           "distinct_step_sigs": len(seen_keys),
           "reg_to_first_result_p50_ms": pct(50),
           "reg_to_first_result_p99_ms": pct(99),
           "steady_state_fps": frames / t_stream}
    if incremental:
        res["step_cache"] = registry.step_cache.snapshot()
        res["leaf_table"] = registry.leaf_table.snapshot()
    return res


def run(smoke: bool = False) -> dict:
    from benchmarks.common import device_topology, emit, save_result

    n_batches = 48 if smoke else 240
    print(f"query churn: {n_batches} batches x {BATCH} frames, "
          f"Poisson arrivals={ARRIVAL_RATE}/batch "
          f"retirements={RETIRE_RATE}/batch (smoke={smoke})")
    base = _run_config(False, n_batches)
    incr = _run_config(True, n_batches)

    p99_speedup = (base["reg_to_first_result_p99_ms"]
                   / max(incr["reg_to_first_result_p99_ms"], 1e-9))
    p50_speedup = (base["reg_to_first_result_p50_ms"]
                   / max(incr["reg_to_first_result_p50_ms"], 1e-9))
    fps_ratio = incr["steady_state_fps"] / base["steady_state_fps"]
    payload = {"batch": BATCH, "smoke": smoke,
               "arrival_rate": ARRIVAL_RATE, "retire_rate": RETIRE_RATE,
               "baseline": base, "incremental": incr,
               "reg_latency_p50_speedup": p50_speedup,
               "reg_latency_p99_speedup": p99_speedup,
               "steady_state_fps_ratio": fps_ratio,
               "device_topology": device_topology()}
    save_result("query_churn", payload)

    emit("query_churn/baseline_reg_p99",
         base["reg_to_first_result_p99_ms"] * 1e3,
         f"p50_ms={base['reg_to_first_result_p50_ms']:.1f};"
         f"compiles={base['total_steps_compiled']}")
    emit("query_churn/incremental_reg_p99",
         incr["reg_to_first_result_p99_ms"] * 1e3,
         f"p50_ms={incr['reg_to_first_result_p50_ms']:.1f};"
         f"compiles={incr['total_steps_compiled']};"
         f"steady_compiles={incr['steady_state_compiles']}")
    for r in (base, incr):
        print(f"{r['config']:>12}: reg->result "
              f"p50={r['reg_to_first_result_p50_ms']:.1f}ms "
              f"p99={r['reg_to_first_result_p99_ms']:.1f}ms | "
              f"{r['rebuilds']} rebuilds "
              f"({r['rebuilds_on_recurring_sig']} recurring-sig) | "
              f"{r['total_steps_compiled']} steps compiled, "
              f"{r['steady_state_compiles']} redundant of "
              f"{r['distinct_step_sigs']} distinct sigs | "
              f"fps={r['steady_state_fps']:.0f}")
    print(f"reg-latency speedup: p50 {p50_speedup:.2f}x, "
          f"p99 {p99_speedup:.2f}x; steady-state fps ratio "
          f"{fps_ratio:.2f}x")
    ok = (incr["steady_state_compiles"] == 0
          and base["steady_state_compiles"] > 0
          and p50_speedup > 1.0)
    print(f"acceptance (incremental compiles 0 steps for already-seen "
          f"signatures, baseline recompiles them, and p50 "
          f"registration latency improves): {'PASS' if ok else 'FAIL'}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale budget; still writes "
                         "results/bench/query_churn.json")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
