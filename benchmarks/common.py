"""Shared benchmark scaffolding.

Budget control: REPRO_BENCH_BUDGET=small|full (default small — CPU
container).  Every benchmark prints ``name,us_per_call,derived`` CSV rows
(harness contract) plus a human-readable table, and returns a dict that
benchmarks/run.py aggregates into results/bench/*.json.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, List

BUDGET = os.environ.get("REPRO_BENCH_BUDGET", "small")


def budget(small: int, full: int) -> int:
    return small if BUDGET == "small" else full


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def timeit(fn: Callable, *args, repeat: int = 5, warmup: int = 2) -> float:
    """Median wall time (us) of fn(*args); blocks on jax outputs."""
    import jax
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def device_topology(mesh=None) -> Dict[str, Any]:
    """Bench provenance: the device layout a result was measured on
    (recorded next to ``calibration_info`` in results/bench/*.json —
    a 1-device CPU number and an 8-forced-host-device number are not
    comparable without it)."""
    import jax
    topo: Dict[str, Any] = {"device_count": jax.device_count(),
                            "platform": jax.default_backend()}
    topo["mesh_shape"] = (
        {name: int(n) for name, n in zip(mesh.axis_names,
                                         mesh.devices.shape)}
        if mesh is not None else None)
    return topo


def save_result(name: str, payload: Dict[str, Any]):
    os.makedirs("results/bench", exist_ok=True)
    with open(f"results/bench/{name}.json", "w") as f:
        json.dump(payload, f, indent=1, default=str)


# -- shared trained filters (several benchmarks evaluate the same branch) --
_FILTER_CACHE: Dict[Any, Any] = {}


def cached_filter(scene, kind: str, steps: int, n_frames: int):
    from repro.models.config import BranchSpec
    from repro.train.filter_train import train_filter
    key = (scene.name, kind, steps, n_frames)
    if key not in _FILTER_CACHE:
        spec = BranchSpec(layer=2, grid=scene.grid,
                          n_classes=scene.n_classes, kind=kind, head_dim=64)
        _FILTER_CACHE[key] = train_filter(scene, spec, steps=steps,
                                          n_frames=n_frames)
    return _FILTER_CACHE[key]
