"""Benchmark harness entry point: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig7,table3]

Emits ``name,us_per_call,derived`` CSV rows (harness contract), prints
human-readable tables, writes JSON artifacts under results/bench/, and
finishes with the roofline summary derived from the dry-run artifacts
(if present).

REPRO_BENCH_BUDGET=full enlarges training budgets (default: small/CPU).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = [
    ("kernel_microbench", "benchmarks.kernel_microbench"),
    ("filter_latency", "benchmarks.filter_latency"),
    ("fig7_count_accuracy", "benchmarks.fig7_count_accuracy"),
    ("fig11_ccf", "benchmarks.fig11_ccf"),
    ("fig15_clf", "benchmarks.fig15_clf"),
    ("table3_query_speedup", "benchmarks.table3_query_speedup"),
    ("table4_cv_variance", "benchmarks.table4_cv_variance"),
    ("multi_query_sharing", "benchmarks.multi_query_sharing"),
    ("query_churn", "benchmarks.query_churn"),
    ("aggregate_contracts", "benchmarks.aggregate_contracts"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark name filter")
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]

    print("name,us_per_call,derived")
    failures = []
    for name, mod_name in BENCHES:
        if only and not any(o in name for o in only):
            continue
        t0 = time.time()
        print(f"\n=== {name} ===", flush=True)
        try:
            import importlib
            mod = importlib.import_module(mod_name)
            mod.run()
            print(f"[{name}] done in {time.time()-t0:.0f}s", flush=True)
        except Exception as e:
            failures.append(name)
            print(f"[{name}] FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()

    # roofline summary (reads dry-run artifacts if the sweep has run)
    try:
        import os
        if os.path.isdir("results/dryrun"):
            from benchmarks import roofline
            recs = roofline.load("results/dryrun")
            if recs:
                print("\n=== roofline (from dry-run artifacts) ===")
                print(roofline.table(recs, "single"))
    except Exception as e:
        print(f"[roofline] skipped: {e}")

    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        sys.exit(1)
    print("\nall benchmarks complete.")


if __name__ == "__main__":
    main()
