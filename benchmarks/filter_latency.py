"""Paper §IV timing claims: filter latency vs oracle latency.

The paper measures ~1.5 ms/frame (IC branch at VGG layer 5) and
1.9 ms/frame (OD branch at Darknet layer 8) against 200 ms/frame for
Mask R-CNN and 15 ms for full YOLOv2 — i.e. the filter costs ~1% of the
oracle.  We measure the same *architectural ratio* on this container:
branch (k trunk layers + head) vs the full backbone forward, on matched
reduced configs, plus the per-layer scaling of the branch point.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import budget, emit, save_result, timeit
from repro.configs import get_smoke_config
from repro.models import model as M
from repro.models.config import BranchSpec
from repro.train.filter_train import (default_trunk, filter_forward,
                                      init_filter_model)


def run() -> dict:
    rng = jax.random.PRNGKey(0)
    B, g, d_in = 32, 8, 64
    trunk = default_trunk(d_model=128, n_layers=8, grid=g)
    out = {}

    embeds = jax.random.normal(rng, (B, g * g, d_in))

    # branch latency vs branch depth k (paper: layer-5 vs layer-15 tradeoff)
    for k in (2, 4, 8):
        spec = BranchSpec(layer=k, grid=g, n_classes=8, kind="od",
                          head_dim=64)
        p = init_filter_model(rng, trunk, spec, d_in)
        fn = jax.jit(lambda pp, e, s=spec: filter_forward(pp, trunk, s, e))
        us = timeit(fn, p, embeds, repeat=5)
        out[f"branch_k{k}_us_per_frame"] = us / B
        emit(f"filter_latency/branch_k{k}", us / B, f"batch={B}")

    # oracle analogue: a *bigger* full backbone (the thing worth gating) —
    # 16 layers x 512 wide vs the 2-of-8-layer x128 branch trunk.  The
    # production ratio is larger still (72B oracle vs 4-layer branch:
    # ~1e4x by FLOPs); this measures the same architectural effect at
    # CPU-runnable scale.
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config("qwen2_0p5b"),
                              n_layers=16, d_model=512, n_heads=8,
                              head_dim=64, d_ff=2048)
    params = M.init_params(rng, cfg)
    toks = jax.random.randint(rng, (B, g * g), 0, cfg.vocab_size)
    fwd = jax.jit(lambda pp, t: M.forward(pp, cfg, t).logits)
    us_oracle = timeit(fwd, params, toks, repeat=5)
    out["oracle_16L512d_us_per_frame"] = us_oracle / B
    emit("filter_latency/oracle_16L512d", us_oracle / B, "")

    ratio = out["oracle_16L512d_us_per_frame"] / out["branch_k2_us_per_frame"]
    flops_ratio = (16 * 512 * 512 * 12) / (2 * 128 * 128 * 12 + 64 * 128)
    out["oracle_to_filter_ratio"] = ratio
    out["oracle_to_filter_flops_ratio"] = flops_ratio
    emit("filter_latency/ratio", 0.0,
         f"oracle/filter={ratio:.1f}x;flops_ratio={flops_ratio:.0f}x")
    save_result("filter_latency", out)

    print("\nFilter latency (per frame):")
    for k, v in out.items():
        print(f"  {k}: {v:.1f}")
    return out


if __name__ == "__main__":
    run()
