#!/usr/bin/env python
"""Documentation consistency checker (``make docs-check``).

Docs rot in three specific ways this repo has been bitten by or wants to
stay ahead of: a renamed file leaves dangling ``docs/*.md`` links, a
renamed Makefile target leaves quickstarts recommending commands that no
longer exist, and prose keeps pointing at modules/tests that moved.  The
checker walks every Markdown file in ``docs/`` plus the README and
validates, with zero third-party dependencies:

1. **Intra-doc links** — every relative ``[text](target)`` resolves to a
   real file (http(s)/mailto links are skipped; ``#anchors`` on local
   links are checked against the target file's headings, GitHub-slug
   style).
2. **Make targets** — every ``make <target>`` mentioned inside a code
   span or fenced block names a target the Makefile actually defines.
3. **File paths** — every path-shaped token inside a code span or fenced
   block (``tools/docs_check.py``, ``core/plan.py``, ...) exists,
   resolved against the repo root, ``src/repro/`` (module paths are
   written repo-root-relative OR package-relative in prose), or the
   document's own directory.  Placeholder paths containing ``<...>``
   (e.g. ``results/calibration/<backend>.json``) are skipped, as are
   absolute paths (machine-local examples like ``/tmp/mon.json``).

Wired into ``make test`` as a prerequisite and into the pytest suite
(tests/test_docs.py), so a PR that breaks a reference fails tier-1.

    python tools/docs_check.py [--root PATH]   # exit 1 + report on rot
"""
from __future__ import annotations

import argparse
import os
import re
import sys
from typing import Dict, List, Set, Tuple

#: files checked, relative to the repo root (docs/*.md is globbed)
EXTRA_FILES = ("README.md",)

#: extensions a backticked token must carry to be treated as a file path
PATH_EXTS = (".py", ".md", ".json", ".txt", ".sh", ".yaml", ".yml",
             ".toml", ".cfg")

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"```.*?```", re.S)
_INLINE_CODE_RE = re.compile(r"`([^`\n]+)`")
_MAKE_RE = re.compile(r"\bmake\s+([A-Za-z0-9_.-]+)")
_PATH_TOKEN_RE = re.compile(
    r"^[A-Za-z0-9_.-]+(?:/[A-Za-z0-9_.-]+)+$")
_TARGET_RE = re.compile(r"^([A-Za-z0-9_.-]+)\s*:([^=]|$)")
_HEADING_RE = re.compile(r"^#+\s+(.*)$", re.M)


def _slugify(heading: str) -> str:
    """GitHub-style anchor slug (close enough for our headings)."""
    s = heading.strip().lower()
    s = re.sub(r"[`*_]", "", s)
    s = re.sub(r"[^\w\s-]", "", s)
    return re.sub(r"[\s]+", "-", s).strip("-")


def _make_targets(root: str) -> Set[str]:
    targets: Set[str] = set()
    path = os.path.join(root, "Makefile")
    if not os.path.exists(path):
        return targets
    with open(path) as f:
        for line in f:
            if line.startswith(("\t", " ", "#", ".")):
                if not line.startswith(".PHONY"):
                    continue
            m = _TARGET_RE.match(line)
            if m:
                targets.add(m.group(1))
            if line.startswith(".PHONY:"):
                targets.update(line.split(":", 1)[1].split())
    return targets


def _code_spans(text: str) -> List[str]:
    """Fenced blocks + inline code spans — where commands/paths live.
    (Prose mentions are deliberately not checked: 'make targets' is
    English, not a build rule.)"""
    spans = _FENCE_RE.findall(text)
    prose = _FENCE_RE.sub(" ", text)
    spans.extend(_INLINE_CODE_RE.findall(prose))
    return spans


def _resolve_path(token: str, root: str, doc_dir: str) -> bool:
    candidates = (os.path.join(root, token),
                  os.path.join(root, "src", "repro", token),
                  os.path.join(doc_dir, token))
    return any(os.path.exists(c) for c in candidates)


def _check_file(md_path: str, root: str, targets: Set[str],
                headings_cache: Dict[str, Set[str]]) -> List[str]:
    errors: List[str] = []
    rel = os.path.relpath(md_path, root)
    with open(md_path) as f:
        text = f.read()
    doc_dir = os.path.dirname(md_path)

    def headings_of(path: str) -> Set[str]:
        if path not in headings_cache:
            with open(path) as hf:
                headings_cache[path] = {
                    _slugify(h) for h in _HEADING_RE.findall(hf.read())}
        return headings_cache[path]

    # 1. links
    for target in _LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        dest = md_path if not path_part \
            else os.path.normpath(os.path.join(doc_dir, path_part))
        if path_part and not os.path.exists(dest):
            errors.append(f"{rel}: broken link -> {target}")
            continue
        if anchor and os.path.isfile(dest) and dest.endswith(".md") \
                and anchor not in headings_of(dest):
            errors.append(f"{rel}: link anchor #{anchor} not a heading "
                          f"of {os.path.relpath(dest, root)}")

    # 2 + 3. commands and paths inside code spans
    for span in _code_spans(text):
        for m in _MAKE_RE.finditer(span):
            if m.group(1) not in targets:
                errors.append(f"{rel}: `make {m.group(1)}` is not a "
                              f"Makefile target")
        for token in re.split(r"[\s,;()'\"]+", span):
            token = token.strip().rstrip(".:")
            token = re.sub(r":\d+$", "", token)      # path.py:123 refs
            if not token or token.startswith(("/", "-")) or "<" in token:
                continue                 # absolute / flag / placeholder
            if not token.endswith(PATH_EXTS):
                continue
            if not _PATH_TOKEN_RE.match(token):
                continue
            if not _resolve_path(token, root, doc_dir):
                errors.append(f"{rel}: referenced path does not exist: "
                              f"{token}")
    return errors


def collect_errors(root: str) -> List[str]:
    """All doc-consistency violations under ``root`` (empty == healthy)."""
    targets = _make_targets(root)
    headings_cache: Dict[str, Set[str]] = {}
    files: List[str] = []
    docs_dir = os.path.join(root, "docs")
    if os.path.isdir(docs_dir):
        files.extend(os.path.join(docs_dir, n)
                     for n in sorted(os.listdir(docs_dir))
                     if n.endswith(".md"))
    files.extend(os.path.join(root, n) for n in EXTRA_FILES
                 if os.path.exists(os.path.join(root, n)))
    errors: List[str] = []
    for path in files:
        errors.extend(_check_file(path, root, targets, headings_cache))
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))),
        help="repo root (default: parent of tools/)")
    args = ap.parse_args(argv)
    errors = collect_errors(args.root)
    if errors:
        print(f"docs-check: {len(errors)} problem(s)")
        for e in errors:
            print(f"  {e}")
        return 1
    print("docs-check: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
