"""End-to-end driver (the paper's kind = video query *serving*).

Trains filter branches on each Table-II-matched stream, then serves the
paper's seven queries (q1–q7 analogues) through the filter cascade with
live straggler accounting — the complete §IV-B experiment as a runnable
program.

    PYTHONPATH=src python examples/monitoring_queries.py \
        [--steps 250] [--frames 2048] [--adaptive]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import cascade as CS
from repro.core import query as Q
from repro.data.synthetic import PRESETS, VideoStream, collect
from repro.models.config import BranchSpec
from repro.train.filter_train import train_filter
from benchmarks.table3_query_speedup import QUERIES, ORACLE_MS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=250)
    ap.add_argument("--frames", type=int, default=1024)
    ap.add_argument("--adaptive", action="store_true",
                    help="adaptive (observed-selectivity) filter ordering")
    args = ap.parse_args()

    filters = {}
    print(f"{'q':4s} {'stream':14s} {'recall':>7s} {'select':>7s} "
          f"{'speedup':>9s} {'filter ms':>9s}")
    for name, scene_name, strict_q, tolerant_q in QUERIES:
        scene = PRESETS[scene_name]
        if scene_name not in filters:
            spec = BranchSpec(layer=2, grid=scene.grid,
                              n_classes=scene.n_classes, kind="od",
                              head_dim=64)
            filters[scene_name] = train_filter(scene, spec,
                                               steps=args.steps,
                                               n_frames=2048)
        tf = filters[scene_name]
        data = collect(VideoStream(scene), args.frames)
        fn = tf.jitted()

        query = strict_q()
        cascade = CS.FilterCascade(tolerant_q(), adaptive=args.adaptive)

        t0 = time.perf_counter()
        fout = fn(tf.params, jnp.asarray(data["embeds"]))
        mask = np.asarray(cascade.mask(fout))
        filter_ms = (time.perf_counter() - t0) / args.frames * 1e3

        truth = np.array([Q.eval_objects(query, o, scene.n_classes,
                                         scene.grid)
                          for o in data["objects"]])
        answers = np.zeros(args.frames, bool)
        for j in np.nonzero(mask)[0]:
            answers[j] = truth[j]       # oracle-exact on survivors
        recall = (answers & truth).sum() / max(truth.sum(), 1)
        sel = mask.mean()
        speedup = (args.frames * ORACLE_MS) / (
            args.frames * filter_ms + mask.sum() * ORACLE_MS)
        print(f"{name:4s} {scene_name:14s} {recall:7.3f} {sel:7.3f} "
              f"{speedup:8.1f}x {filter_ms:9.2f}")


if __name__ == "__main__":
    main()
