"""Quickstart: the paper's pipeline in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. generate a synthetic monitoring stream (Jackson-like: cars + people),
2. train an OD filter branch for a few steps (counts + location grid),
3. execute a declarative query with the filter cascade,
4. estimate an aggregate with a control variate.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import aggregates as AGG
from repro.core import cascade as CS
from repro.core import query as Q
from repro.data.synthetic import JACKSON_LIKE, VideoStream, collect
from repro.models.config import BranchSpec
from repro.train.filter_train import train_filter

# 1. stream ---------------------------------------------------------------
scene = JACKSON_LIKE
data = collect(VideoStream(scene), 512)
print(f"stream: {len(data['objects'])} frames, "
      f"{data['counts'].sum(-1).mean():.1f} objects/frame")

# 2. filter branch (paper §II-B) -------------------------------------------
spec = BranchSpec(layer=2, grid=scene.grid, n_classes=scene.n_classes,
                  kind="od", head_dim=64)
tf = train_filter(scene, spec, steps=120, n_frames=1024)
print(f"filter trained; final loss {np.mean(tf.losses[-10:]):.3f}")

# 3. declarative query via cascade (paper §IV-B) ---------------------------
#    "frames with >=1 car and >=1 person, car left of person"
query = Q.And((Q.ClassCount(0, Q.Op.GE, 1, tolerance=1),
               Q.ClassCount(1, Q.Op.GE, 1, tolerance=1),
               Q.Spatial(0, Q.Rel.LEFT, 1, radius=2)))
cascade = CS.FilterCascade(query)
fn = tf.jitted()
ex = CS.CascadeExecutor(
    cascade,
    filter_fn=lambda b: fn(tf.params, jnp.asarray(data["embeds"])),
    oracle_fn=lambda b, idx: [data["objects"][j] for j in idx],
    n_classes=scene.n_classes, grid=scene.grid)
res = ex.run_batch(jnp.asarray(data["embeds"]))
truth = np.array([Q.eval_objects(query, o, scene.n_classes, scene.grid)
                  for o in data["objects"]])
recall = (res.answers & truth).sum() / max(truth.sum(), 1)
print(f"cascade: selectivity {ex.stats.selectivity:.2f}, "
      f"oracle calls {ex.stats.oracle_calls}/{len(truth)}, "
      f"recall {recall:.2f}, "
      f"speedup {ex.stats.speedup_vs_full(200.0, 1.9):.1f}x "
      f"(paper cost model: 200ms oracle, 1.9ms filter)")

# 4. aggregate with a control variate (paper §III) -------------------------
y = truth.astype(float)                                # oracle answer
x = np.asarray(res.answers, float)                     # filter+oracle answer
fout = fn(tf.params, jnp.asarray(data["embeds"]))
x_filter = np.asarray(Q.eval_filters(query, fout), float)
est = AGG.cv_estimate(y, x_filter)
print(f"aggregate: naive mean {y.mean():.4f}, CV mean {est.mean:.4f}, "
      f"variance reduction {est.variance_reduction:.1f}x, "
      f"95% CI ±{1.96*np.sqrt(est.var):.4f}")
print("quickstart OK")
