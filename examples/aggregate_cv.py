"""Monitoring aggregates with control variates (paper §III demo).

Estimates "fraction of frames where a car is in the lower-right quadrant"
(a1-style) three ways — naive sampling, single CV, multiple CV — and
shows the variance/CI shrink while the mean stays unbiased.  Also
demonstrates the distributed (mergeable-accumulator) path.

    PYTHONPATH=src python examples/aggregate_cv.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import aggregates as AGG
from repro.core import query as Q
from repro.data.synthetic import DETRAC_LIKE, VideoStream, collect
from repro.models.config import BranchSpec
from repro.train.filter_train import train_filter

scene = DETRAC_LIKE
g = scene.grid
n_frames, n_samples = 2048, 400

print("training OD filter on detrac-like stream...")
spec = BranchSpec(layer=2, grid=g, n_classes=scene.n_classes, kind="od",
                  head_dim=64)
tf = train_filter(scene, spec, steps=200, n_frames=1536)
data = collect(VideoStream(scene), n_frames)
fn = tf.jitted()
fout = fn(tf.params, jnp.asarray(data["embeds"]))

region_q = Q.Region(0, (g // 2, g // 2, g, g))
count_q = Q.Count(Q.Op.GE, 3)

rng = np.random.default_rng(0)
idx = rng.choice(n_frames, n_samples, replace=False)

# oracle answers (Y) on the sample
y = np.array([float(Q.eval_objects(Q.And((region_q, count_q)),
                                   data["objects"][i], scene.n_classes, g))
              for i in idx])
# filter answers (controls, Z)
z_region = np.asarray(Q.eval_filters(
    Q.Region(0, (g // 2, g // 2, g, g), radius=1), fout), float)[idx]
z_count = np.asarray(Q.eval_filters(Q.Count(Q.Op.GE, 3, tolerance=1),
                                    fout), float)[idx]

true_mean = np.mean([float(Q.eval_objects(Q.And((region_q, count_q)), o,
                                          scene.n_classes, g))
                     for o in data["objects"]])

naive_var = y.var(ddof=1) / len(y)
single = AGG.cv_estimate(y, z_region)
multi = AGG.mcv_estimate(y, np.stack([z_region, z_count], 1))

print(f"\npopulation mean (all {n_frames} frames): {true_mean:.4f}")
print(f"{'estimator':18s} {'mean':>8s} {'var':>12s} {'reduction':>10s} "
      f"{'95% CI':>16s}")
for name, mean, var in [("naive", y.mean(), naive_var),
                        ("single CV", single.mean, single.var),
                        ("multiple CV", multi.mean, multi.var)]:
    h = 1.96 * np.sqrt(var)
    print(f"{name:18s} {mean:8.4f} {var:12.3e} {naive_var/var:9.1f}x "
          f"[{mean-h:.4f}, {mean+h:.4f}]")

# distributed accumulators: 4 shards merged (psum-tree algebra)
accs = []
for shard in np.array_split(np.arange(len(y)), 4):
    acc = AGG.CVAccumulator.init(2).update(
        jnp.asarray(y[shard]),
        jnp.asarray(np.stack([z_region[shard], z_count[shard]], 1)))
    accs.append(acc)
merged = accs[0]
for a in accs[1:]:
    merged = merged.merge(a)
est = merged.estimate()
print(f"\n4-shard merged accumulator: mean {est.mean:.4f} "
      f"(matches multiple CV: {abs(est.mean - multi.mean) < 1e-6})")
