"""Backbone serving substrate demo: batched requests with KV caches.

Serves a reduced qwen2-0.5b-family model: batched prefill, then a decode
loop with the cache layout the dry-run shards over the production mesh.
Also demonstrates live-stream ingestion with the straggler-drop policy.

    PYTHONPATH=src python examples/serve_stream.py [--batch 8] [--steps 24]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.streaming import StragglerPolicy, StreamExecutor
from repro.models import model as M, serve as SV


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config("qwen2_0p5b")
    rng = jax.random.PRNGKey(0)
    params = M.init_params(rng, cfg)
    B = args.batch

    prompts = jax.random.randint(rng, (B, args.prompt_len), 0,
                                 cfg.vocab_size)
    max_len = args.prompt_len + args.steps + 8
    cache = SV.init_cache(cfg, B, max_len)

    prefill = jax.jit(lambda p, t, c: SV.prefill(p, cfg, t, cache=c)[:2])
    decode = jax.jit(lambda p, t, c: SV.decode_step(p, cfg, t, cache=c))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts, cache)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {B}x{args.prompt_len} tokens in {t_prefill*1e3:.0f} ms "
          f"({B*args.prompt_len/t_prefill:.0f} tok/s)")

    tok = jnp.argmax(logits, -1)[:, None]
    t0 = time.perf_counter()
    outs = [tok]
    for _ in range(args.steps):
        logits, cache = decode(params, outs[-1], cache)
        outs.append(jnp.argmax(logits, -1)[:, None])
    jax.block_until_ready(outs[-1])
    t_dec = time.perf_counter() - t0
    print(f"decode: {args.steps} steps x {B} streams in {t_dec*1e3:.0f} ms "
          f"({B*args.steps/t_dec:.0f} tok/s); cache len "
          f"{int(cache['len'])}")

    # live stream with straggler mitigation
    def process(idx):
        decode(params, outs[-1], cache)

    ex = StreamExecutor(process, batch=B,
                        policy=StragglerPolicy(fps=240.0, slack=1.0))
    st = ex.run(20 * B)
    print(f"stream: {st.frames_processed} processed, "
          f"{st.frames_dropped} dropped (deadline policy), "
          f"{st.fps:.0f} fps")


if __name__ == "__main__":
    main()
