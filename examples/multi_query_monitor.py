"""Multi-query monitor: N concurrent queries, one shared cascade.

Demonstrates the multi-query subsystem end to end on a synthetic stream:

- ``QueryRegistry``          — live query set with epoch versioning; owns
                               the population ``SlotStats`` store that
                               survives plan rebuilds
- ``MultiQueryCascade``      — deduplicating shared-plan filter evaluation,
                               run *staged and adaptive* here: cost tiers
                               ordered by learned population pass rates,
                               later tiers skipped once every query is
                               decided (watch the staging report lines)
- ``MultiQueryExecutor``     — ONE union-mask oracle compaction per batch
                               (dense ``oracle_bucket`` index batches),
                               per-query attribution in the stats
- ``MultiQueryStreamExecutor`` — hopping windows that multiplex query
                               registrations/retirements mid-stream (the
                               shared plan is rebuilt only when the
                               registered set changes; each rebuild hands
                               the registry's SlotStats to the new engine,
                               so mid-stream registrations inherit the
                               learned selectivities instead of starting
                               cold)

Filter outputs are derived from the stream's ground truth (oracle-grade
branch heads) so the example runs in seconds without training; swap in
``train_filter`` heads (see examples/monitoring_queries.py) for the
learned-filter version.

    PYTHONPATH=src python examples/multi_query_monitor.py [--frames 1024]

``--stats PATH`` persists the population store across runs
(``SlotStats.save``/``load`` via ``QueryRegistry(stats_path=...)``): the
second invocation resumes with the first one's learned selectivities and
row ledger instead of relearning them from the prior.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import cascade as CS
from repro.core import query as Q
from repro.core.filters import FilterOutputs
from repro.core.streaming import (HoppingWindow, MultiQueryStreamExecutor,
                                  QueryRegistry)
from repro.data.synthetic import PRESETS, VideoStream, collect


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=1024)
    ap.add_argument("--window", type=int, default=256)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--stats", default=None, metavar="PATH",
                    help="persist SlotStats here across runs (loaded at "
                         "start when present, saved at exit)")
    args = ap.parse_args()

    scene = PRESETS["jackson-like"]
    data = collect(VideoStream(scene), args.frames)
    counts = jnp.asarray(data["counts"].astype(np.float32))
    grid = jnp.where(jnp.asarray(data["occupancy"]), 1.0, 0.0)

    registry = QueryRegistry(stats_path=args.stats)
    if args.stats and len(registry.slot_stats):
        print(f"resumed {len(registry.slot_stats)} learned slot rates "
              f"from {args.stats}")
    q_busy = registry.register(Q.Count(Q.Op.GE, 3))
    q_car = registry.register(Q.ClassCount(0, Q.Op.GE, 1))
    q_order = registry.register(
        Q.And((Q.ClassCount(0, Q.Op.GE, 1),
               Q.Spatial(0, Q.Rel.LEFT, 1, radius=1))))
    names = {q_busy: "busy", q_car: "car>=1", q_order: "car-left-of"}

    engines = []

    def engine_factory(queries, slot_stats):
        """(queries, registry SlotStats) -> fn(frame_indices) -> (B, N)
        bool.  Rebuilt only on registry epoch changes (watch
        ``executor.rebuilds``); the shared ``slot_stats`` store carries
        the learned pass rates across rebuilds."""
        mqc = CS.MultiQueryCascade(queries, adaptive=True,
                                   slot_stats=slot_stats, restage_every=4)

        def filter_fn(idx):
            return FilterOutputs(counts=counts[idx], grid=grid[idx])

        def oracle_fn(idx, sel):                 # union-of-needs compaction
            return [[tuple(o) for o in data["objects"][idx[j]]]
                    for j in sel]

        ex = CS.MultiQueryExecutor(mqc, filter_fn, oracle_fn,
                                   scene.n_classes, scene.grid,
                                   oracle_bucket=16)
        engines.append((ex, queries))
        return lambda idx: ex.run_batch(idx).answers

    executor = MultiQueryStreamExecutor(
        registry, engine_factory,
        HoppingWindow(size=args.window, advance=args.window), args.batch)

    def on_window(res):
        lo, hi = res.span
        hits = ", ".join(f"{names[qid]}={n}" for qid, n in
                         sorted(res.hits.items()))
        casc = engines[-1][0].cascade
        rep = casc.staging_report
        # the report describes the last batch that actually ran staged;
        # when staging is parked it would be stale — show the mode only
        staging = (f"  [stages {len(rep.ran)}/{len(rep.order)} ran, "
                   f"mode={casc.mode}]" if rep and casc.mode == "staged"
                   else f"  [mode={casc.mode}]")
        print(f"window [{lo:5d}, {hi:5d})  {hits}{staging}")
        if lo == 0:                       # mid-stream registration
            qid = registry.register(Q.Not(Q.ClassCount(1, Q.Op.GE, 1)))
            names[qid] = "no-person"
            print("  -> registered 'no-person' (takes effect next batch; "
                  f"inherits {len(registry.slot_stats)} learned slot rates)")
        if lo == args.window:             # mid-stream retirement
            registry.retire(q_busy)
            print("  -> retired 'busy'")

    executor.run(args.frames, on_window)
    print(f"\nplan rebuilds: {executor.rebuilds} "
          f"(one per registry change, never per batch)")
    ex, queries = engines[-1]                    # current engine's stats
    st = ex.stats
    print(f"last engine: {st.frames_in} frames in, "
          f"{st.oracle_calls} oracle calls (union of needs); per-query "
          f"attribution: " + ", ".join(
              f"{names[qid]}={n}" for (qid, _), n in
              zip(registry.active(), st.per_query_pass)))
    print(f"population stats: {len(registry.slot_stats)} slots learned "
          f"across {executor.rebuilds} engine rebuilds (stats survive "
          f"registration churn)")
    if args.stats:
        registry.save_stats()
        print(f"saved population stats to {args.stats} — the next run "
              f"resumes warm (stats survive restarts too)")


if __name__ == "__main__":
    main()
