"""Multi-query monitor: N concurrent queries, one shared cascade.

Demonstrates the multi-query subsystem end to end on a synthetic stream:

- ``QueryRegistry``          — live query set with epoch versioning
- ``MultiQueryCascade``      — deduplicating shared-plan filter evaluation
- ``MultiQueryExecutor``     — ONE union-mask oracle compaction per batch,
                               per-query attribution in the stats
- ``MultiQueryStreamExecutor`` — hopping windows that multiplex query
                               registrations/retirements mid-stream (the
                               shared plan is rebuilt only when the
                               registered set changes)

Filter outputs are derived from the stream's ground truth (oracle-grade
branch heads) so the example runs in seconds without training; swap in
``train_filter`` heads (see examples/monitoring_queries.py) for the
learned-filter version.

    PYTHONPATH=src python examples/multi_query_monitor.py [--frames 1024]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import cascade as CS
from repro.core import query as Q
from repro.core.filters import FilterOutputs
from repro.core.streaming import (HoppingWindow, MultiQueryStreamExecutor,
                                  QueryRegistry)
from repro.data.synthetic import PRESETS, VideoStream, collect


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=1024)
    ap.add_argument("--window", type=int, default=256)
    ap.add_argument("--batch", type=int, default=64)
    args = ap.parse_args()

    scene = PRESETS["jackson-like"]
    data = collect(VideoStream(scene), args.frames)
    counts = jnp.asarray(data["counts"].astype(np.float32))
    grid = jnp.where(jnp.asarray(data["occupancy"]), 1.0, 0.0)

    registry = QueryRegistry()
    q_busy = registry.register(Q.Count(Q.Op.GE, 3))
    q_car = registry.register(Q.ClassCount(0, Q.Op.GE, 1))
    q_order = registry.register(
        Q.And((Q.ClassCount(0, Q.Op.GE, 1),
               Q.Spatial(0, Q.Rel.LEFT, 1, radius=1))))
    names = {q_busy: "busy", q_car: "car>=1", q_order: "car-left-of"}

    engines = []

    def engine_factory(queries):
        """queries -> fn(frame_indices) -> (B, N) bool.  Rebuilt only on
        registry epoch changes (watch ``executor.rebuilds``)."""
        mqc = CS.MultiQueryCascade(queries)

        def filter_fn(idx):
            return FilterOutputs(counts=counts[idx], grid=grid[idx])

        def oracle_fn(idx, sel):                 # union-of-needs compaction
            return [[tuple(o) for o in data["objects"][idx[j]]]
                    for j in sel]

        ex = CS.MultiQueryExecutor(mqc, filter_fn, oracle_fn,
                                   scene.n_classes, scene.grid)
        engines.append((ex, queries))
        return lambda idx: ex.run_batch(idx).answers

    executor = MultiQueryStreamExecutor(
        registry, engine_factory,
        HoppingWindow(size=args.window, advance=args.window), args.batch)

    def on_window(res):
        lo, hi = res.span
        hits = ", ".join(f"{names[qid]}={n}" for qid, n in
                         sorted(res.hits.items()))
        print(f"window [{lo:5d}, {hi:5d})  {hits}")
        if lo == 0:                       # mid-stream registration
            qid = registry.register(Q.Not(Q.ClassCount(1, Q.Op.GE, 1)))
            names[qid] = "no-person"
            print("  -> registered 'no-person' (takes effect next batch)")
        if lo == args.window:             # mid-stream retirement
            registry.retire(q_busy)
            print("  -> retired 'busy'")

    executor.run(args.frames, on_window)
    print(f"\nplan rebuilds: {executor.rebuilds} "
          f"(one per registry change, never per batch)")
    ex, queries = engines[-1]                    # current engine's stats
    st = ex.stats
    print(f"last engine: {st.frames_in} frames in, "
          f"{st.oracle_calls} oracle calls (union of needs); per-query "
          f"attribution: " + ", ".join(
              f"{names[qid]}={n}" for (qid, _), n in
              zip(registry.active(), st.per_query_pass)))


if __name__ == "__main__":
    main()
